"""Deterministic fault injection for the serving stack (PR 9).

Chaos testing only works when the chaos is REPRODUCIBLE: a failure a CI
job provokes must be the same failure a developer replays locally. A
`FaultPlan` is a seeded set of rules bound to NAMED SITES in the serving
path; each site draws from its own `random.Random(f"{seed}:{site}")`
stream, so whether (and when) a site fires is a pure function of
(plan seed, per-site evaluation order) — independent of thread
interleaving across sites, wall clock, or which other sites exist.

Sites (the catalog; also ROADMAP "Robustness"):

  ===================  =====================================================
  site                 where it fires
  ===================  =====================================================
  serve.dispatch       inside `MicroBatcher._dispatch`, before the engine
                       call — a raise fails every future of that tick with
                       `InjectedFault` (typed, never torn)
  serve.slow_tick      same place, mode="sleep" — injected dispatch latency
                       (deadline pressure without load)
  serve.transfer       inside the scheduler's COMPLETION stage (PR 10),
                       before the tick's single D2H `jax.device_get` — a
                       raise fails exactly that tick's futures with
                       `InjectedFault` while later in-flight ticks keep
                       completing; mode="sleep" models a slow host
                       read-back (transfer-bound deadline pressure)
  index.rebuild        top of `ReverseKRanksEngine.rebuild` — a failing
                       Algorithm-1 build (exercises the maintenance loop's
                       backoff + recovery)
  index.publish        top of `SnapshotManager.publish` — a hot-swap that
                       dies between build and pointer install
  maintenance.loop     inside `MaintenanceLoop`'s poll iteration, OUTSIDE
                       the rebuild try/except — kills the loop thread (the
                       `maintenance_thread_alive` gauge must flip)
  audit.loop           inside `QualityAuditor`'s scoring loop, OUTSIDE the
                       per-item try/except — kills the auditor thread
  persist.wal_write    inside `IndexPersister.append` — a WAL write error
                       (the engine must keep serving, WAL disabled until
                       the next spill re-baselines)
  persist.spill        inside `IndexPersister.spill` — mode="torn"
                       truncates the spill mid-write (recovery must detect
                       it by checksum, never load it)
  ===================  =====================================================

Zero-overhead contract
----------------------
Instrumented sites pay exactly ONE module-global flag check when
injection is disabled::

    from repro.serve import faults
    ...
    if faults.ACTIVE is not None:
        faults.fire("serve.dispatch")

`ACTIVE` is `None` unless a plan is installed (`install`), so the
disabled-path cost is an attribute read + `is not None` — the
`perf_engine --serve` ≤ 1.03× overhead gate covers it.

Enabling
--------
Programmatic: ``faults.install(FaultPlan(seed=0, rules=[...]))`` (tests,
`perf_engine --faults`). Environment: set ``REPRO_FAULTS`` to a spec
string before the process imports this module, e.g.::

    REPRO_FAULTS="index.rebuild:raise:1.0:2,serve.slow_tick:sleep:0.1::25"
    REPRO_FAULTS_SEED=7

Spec grammar: comma-separated rules ``site:mode[:rate[:max_fires
[:latency_ms]]]`` (empty fields keep defaults). Modes: ``raise`` (raise
`InjectedFault`), ``sleep`` (sleep `latency_ms`), ``torn`` (no raise —
the site itself implements the corruption and asks `should_fire`).
"""
from __future__ import annotations

import dataclasses
import os
import random
import threading
import time
from typing import Dict, List, Optional, Sequence

from repro.obs import registry as obs

# The fault-site catalog (kept in one place so tests and docs cannot
# drift from the instrumented call sites).
SITES = (
    "serve.dispatch",
    "serve.slow_tick",
    "serve.transfer",
    "index.rebuild",
    "index.publish",
    "maintenance.loop",
    "audit.loop",
    "persist.wal_write",
    "persist.spill",
)

_MODES = ("raise", "sleep", "torn")


class InjectedFault(RuntimeError):
    """An error raised by the fault-injection harness (never by real
    code) — chaos tests assert on THIS type so an injected failure can
    never be confused with a genuine bug the test provoked."""

    def __init__(self, site: str):
        super().__init__(f"injected fault at site {site!r}")
        self.site = site


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One site's firing rule.

    site:       a name from `SITES` (unknown names are rejected — a typo
                must not silently disable a chaos test).
    mode:       "raise" | "sleep" | "torn" (see module doc).
    rate:       per-evaluation firing probability (1.0 = every time).
    max_fires:  stop firing after this many fires (None = unbounded) —
                "the first two rebuilds fail, then recovery succeeds".
    after:      skip the first `after` evaluations (let warm-up pass).
    latency_ms: sleep duration for mode="sleep".
    """

    site: str
    mode: str = "raise"
    rate: float = 1.0
    max_fires: Optional[int] = None
    after: int = 0
    latency_ms: float = 0.0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"catalog: {list(SITES)}")
        if self.mode not in _MODES:
            raise ValueError(f"unknown fault mode {self.mode!r}; "
                             f"one of {list(_MODES)}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1]; got {self.rate}")


class FaultPlan:
    """A seeded, deterministic set of `FaultRule`s.

    Per-site determinism: each site owns a `random.Random(f"{seed}:{site}")`
    stream advanced once per evaluation of that site, so the fire pattern
    at one site does not depend on how often OTHER sites are evaluated —
    the property that makes multi-threaded chaos runs replayable.
    """

    def __init__(self, seed: int = 0, rules: Sequence[FaultRule] = ()):
        self.seed = int(seed)
        self.rules: Dict[str, FaultRule] = {}
        for r in rules:
            if r.site in self.rules:
                raise ValueError(f"duplicate rule for site {r.site!r}")
            self.rules[r.site] = r
        self._lock = threading.Lock()
        self._rngs = {site: random.Random(f"{self.seed}:{site}")
                      for site in self.rules}
        self.evaluations: Dict[str, int] = {s: 0 for s in self.rules}
        self.fires: Dict[str, int] = {s: 0 for s in self.rules}
        self._m_fired = {
            s: obs.get_default().counter(
                "faults_injected_total", "fault-injection site fires",
                labels={"site": s})
            for s in self.rules}

    @classmethod
    def parse(cls, spec: str, seed: int = 0) -> "FaultPlan":
        """Build a plan from the ``REPRO_FAULTS`` spec grammar
        (module docstring)."""
        rules: List[FaultRule] = []
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            f = part.split(":")
            if len(f) < 2:
                raise ValueError(
                    f"bad fault spec {part!r}: need site:mode[...]")
            rules.append(FaultRule(
                site=f[0], mode=f[1],
                rate=float(f[2]) if len(f) > 2 and f[2] else 1.0,
                max_fires=(int(f[3]) if len(f) > 3 and f[3] else None),
                latency_ms=(float(f[4]) if len(f) > 4 and f[4] else 0.0)))
        return cls(seed=seed, rules=rules)

    def _evaluate(self, site: str) -> Optional[FaultRule]:
        """Advance the site's stream; the rule when it fires, else None."""
        rule = self.rules.get(site)
        if rule is None:
            return None
        with self._lock:
            n = self.evaluations[site]
            self.evaluations[site] = n + 1
            if n < rule.after:
                return None
            if rule.max_fires is not None and \
                    self.fires[site] >= rule.max_fires:
                return None
            draw = self._rngs[site].random()
            if draw >= rule.rate:
                return None
            self.fires[site] += 1
        self._m_fired[site].inc()
        return rule


# The module-global plan — `None` means injection is OFF, and every
# instrumented site's disabled-path cost is the one `is not None` check.
ACTIVE: Optional[FaultPlan] = None


def install(plan: FaultPlan) -> FaultPlan:
    """Install `plan` process-wide (replacing any previous plan)."""
    global ACTIVE
    ACTIVE = plan
    return plan


def clear() -> None:
    """Disable injection (restores the zero-overhead path)."""
    global ACTIVE
    ACTIVE = None


def fire(site: str) -> None:
    """Evaluate `site` against the active plan: raise `InjectedFault`
    (mode="raise"), sleep (mode="sleep"), or do nothing. Call sites gate
    on ``faults.ACTIVE is not None`` FIRST — this function is never on
    the disabled path."""
    plan = ACTIVE
    if plan is None:
        return
    rule = plan._evaluate(site)
    if rule is None:
        return
    if rule.mode == "sleep":
        time.sleep(rule.latency_ms / 1e3)
        return
    if rule.mode == "raise":
        raise InjectedFault(site)
    # mode="torn": the site asks `should_fire` instead; reaching here
    # through fire() is a plan-authoring error — treat as no-op.


def should_fire(site: str) -> bool:
    """Evaluate `site` and report whether it fired, WITHOUT raising —
    for sites that implement the failure themselves (torn spill files,
    WAL write errors where the caller owns the corruption)."""
    plan = ACTIVE
    if plan is None:
        return False
    return plan._evaluate(site) is not None


def _install_from_env() -> None:
    spec = os.environ.get("REPRO_FAULTS")
    if spec:
        install(FaultPlan.parse(
            spec, seed=int(os.environ.get("REPRO_FAULTS_SEED", "0"))))


_install_from_env()
