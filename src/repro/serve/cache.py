"""Caching query backend: within-tick dedupe + cross-tick LRU reuse.

Reverse-MIPS serving workloads are dominated by HOT queries — the same
promoted items get asked about again and again (Amagata & Hara,
arXiv:2110.07131) — and the micro-batching scheduler makes duplicates
even more likely by packing temporally-close requests into one tick.
`CachingBackend` wraps ANY registered inner backend and exploits both:

  * within a tick, exact-duplicate query rows (same bytes, same k/c) are
    deduped BEFORE dispatch — the inner backend sees one column per
    distinct query (the scheduler's pad rows collapse for free, since
    edge padding repeats a real query);
  * across ticks, per-query `QueryResult`s are kept in an LRU keyed by
    (query bytes, k, c), so a hot query is answered without touching the
    rank table at all.

Resolved from the registry as `"cached:<inner>"`::

    eng = ReverseKRanksEngine.build(..., backend="cached:fused")
    eng.query_batch(qs, k=10, c=2.0)        # dedupes + caches

Bit-identity contract (asserted in tests/test_serve.py): cached, deduped,
and full uncached dispatch agree BITWISE, because a batched matmul's
output column depends only on the user matrix, that query column, and the
accumulation order — not on the other columns' values. The accumulation
order does change for width-1 dispatches (matvec lowering), so the
miss-block is padded to width 2 whenever dedupe would shrink a multi-
query tick to a single column (`_MIN_DISPATCH`); a true B = 1 call
dispatches width 1 and matches uncached B = 1 execution exactly.

The cache is invalidated whenever the (rank_table, users, delta) identity
it was filled under changes — for the epoch-versioned mutable engine
(`repro.index`) that is exactly a snapshot-generation change, so a
mutation or rebuild hot-swap never serves a stale-epoch entry.
Results are cached per (k, c) — the selection is a function of both —
and the wrapped result keeps the inner backend's QueryResult shape
contract (e.g. "cached:sharded" still returns (B, k·P) candidate-set
bounds, not (B, n)).
"""
from __future__ import annotations

import threading
import weakref
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backends as BK
from repro.core.types import QueryResult, RankTable, StoredUsers
from repro.obs import registry as obs
from repro.obs import trace

# Never let dedupe shrink a multi-query dispatch to one column: width-1
# matmuls lower as matvecs with a different accumulation order, which
# would break the bitwise cached == uncached contract (module docstring).
_MIN_DISPATCH = 2


def _canonical_key_row(row: np.ndarray) -> np.ndarray:
    """One bit pattern per semantically-equal query row, for cache keying.

    `row + 0.0` maps −0.0 → +0.0 (IEEE 754 addition; every other value,
    including NaN and ±inf, is returned unchanged in VALUE) and gives a
    fresh array we may edit; any NaN coordinate is then rewritten to the
    single canonical qNaN pattern, collapsing payload/sign variants.
    Scoring is payload-blind (x·NaN is NaN for every payload), so rows
    differing only in these bits get identical QueryResults and must get
    identical keys.
    """
    out = row + row.dtype.type(0.0)
    nan = np.isnan(out)
    if nan.any():
        out[nan] = row.dtype.type(np.nan)
    return out


class CachingBackend(BK.QueryBackend):
    """Wrap an inner QueryBackend with dedupe + per-query LRU caching.

    `capacity` is in ENTRIES, and an entry is a full per-query
    QueryResult — for the in-memory backends that includes the (n,)
    r↓/r↑ bound vectors, ≈ 8n bytes each (the "sharded" wrapper's
    candidate-set results are only ≈ 8·k·P). Size it from the per-entry
    cost: the default 512 is ~80 MiB at n = 20k; a million-user index
    wants either a smaller capacity or the sharded inner backend.

    NEAR-DUPLICATE caching (PR 5, opt-in): `quantize_key_bits = b` keys
    the LRU on the QUANTIZED query bytes instead of the exact bytes —
    each coordinate is snapped to a 2^(b−1)-level grid under a
    power-of-two per-query scale (the storage tier's quantizer, applied
    to the key only). Queries within roughly half a grid step per
    coordinate then SHARE an entry: a hot item's jittered re-asks become
    hits at a bounded quality cost (the served result is the exact
    answer of a query within the cell — the rank perturbation is the
    same order as the c-approximation slack for small cells). The
    default None keeps the exact-byte contract (bitwise cached ==
    uncached); with quantization enabled the bit-identity contract
    deliberately WEAKENS to per-cell identity — measure the
    hit-rate/overall-ratio tradeoff with `perf_engine --serve`.
    """

    def __init__(self, inner="dense", *, capacity: int = 512, mesh=None,
                 quantize_key_bits: Optional[int] = None):
        super().__init__(mesh=mesh)
        self.inner = BK.get_backend(inner, mesh=mesh)
        self.name = f"cached:{self.inner.name}"
        self.capacity = int(capacity)
        if quantize_key_bits is not None and not (
                2 <= int(quantize_key_bits) <= 15):
            raise ValueError("quantize_key_bits must be in [2, 15] "
                             f"(int16 grid); got {quantize_key_bits}")
        self.quantize_key_bits = (None if quantize_key_bits is None
                                  else int(quantize_key_bits))
        self._lru: "OrderedDict[tuple, QueryResult]" = OrderedDict()
        self._epoch: Optional[tuple] = None
        # LRU/epoch state is touched from the scheduler's dispatcher
        # thread AND (since PR 10) from client threads probing on the
        # admission path (`MicroBatcher.submit` → `lookup_only`) — an
        # RLock because `query_batch`'s guarded insert loop calls the
        # guarded `_insert`.
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # Registry mirrors of the instance counters (shared across every
        # CachingBackend in the process — a fleet dashboard wants totals,
        # the per-instance attributes stay the fine-grained surface).
        reg = obs.get_default()
        self._m_hits = reg.counter("cache_hits_total", "LRU lookup hits")
        self._m_misses = reg.counter("cache_misses_total",
                                     "LRU lookup misses")
        self._m_evictions = reg.counter("cache_evictions_total",
                                        "entries evicted at capacity")
        self._m_size = reg.gauge("cache_entries",
                                 "live entries in the LRU")

    def _key_bytes(self, row: np.ndarray) -> bytes:
        # Canonicalize BEFORE keying on raw bytes: f32 has distinct bit
        # patterns for semantically identical queries (−0.0 vs +0.0, and
        # 2^24−2 NaN payloads — any NaN coordinate makes every score NaN,
        # so all-NaN-payload queries produce the same answer). Keying the
        # raw pattern made such re-asks LRU misses; with quantization the
        # −0.0 case additionally slipped through np.round (round(−0.0·s)
        # = −0.0 → int16 0 on every path EXCEPT the amax==0/non-finite
        # raw-bytes fallbacks, which re-exposed the raw pattern).
        row = _canonical_key_row(row)
        if self.quantize_key_bits is None:
            return row.tobytes()
        amax = float(np.max(np.abs(row)))
        if amax == 0.0 or not np.isfinite(amax):
            return row.tobytes()
        # power-of-two scale bucket: near-duplicates keep the same
        # exponent except at bucket edges (a bounded miss source)
        exp = int(np.ceil(np.log2(amax)))
        levels = float(2 ** (self.quantize_key_bits - 1) - 1)
        q = np.round(row * (levels / 2.0 ** exp)).astype(np.int16)
        return q.tobytes() + exp.to_bytes(2, "little", signed=True)

    # ----------------------------------------------------------- plumbing
    def bound_ranks(self, rt, users, qs):
        """Step 1 is delegated uncached — bounds are an internal debugging
        surface; caching applies to the end-to-end per-query result."""
        return self.inner.bound_ranks(rt, users, qs)

    def clear(self) -> None:
        with self._lock:
            self._lru.clear()
            self._epoch = None

    def build_index(self, users, items, cfg, key):
        """Builds run on the wrapped backend's substrate."""
        return self.inner.build_index(users, items, cfg, key)

    def check_users_shape(self, n):
        return self.inner.check_users_shape(n)

    def degrade(self, level):
        """Ladder levels act on the wrapped execution backend."""
        self.inner.degrade(level)

    def _check_epoch(self, rt: RankTable, users: jax.Array,
                     delta=None) -> None:
        """Cached results are only valid for the index GENERATION they
        were computed against; key the cache generation on the array
        identities — rank table, users, AND the delta-correction arrays
        (a mutation that only changes the delta buffer changes every
        result too). Snapshot generations are immutable (`repro.index`),
        so identity equality is exactly epoch equality: any hot-swap or
        mutation drops every stale-epoch entry before the next lookup.
        Identities are held as WEAK references — a bare id() could be
        recycled by a rebuilt index landing at the same address, silently
        serving stale results, while strong references would pin the old
        table in memory."""
        if isinstance(users, StoredUsers):
            users = users.rows          # tuples aren't weakref'able; the
        arrays = (rt.thresholds, rt.table, users)   # rows array is 1:1
        if delta is not None:
            arrays += (delta.add_scores, delta.del_scores, delta.user_live)
        if (self._epoch is None or len(self._epoch) != len(arrays)
                or any(ref() is not a
                       for ref, a in zip(self._epoch, arrays))):
            self._lru.clear()
            self._epoch = tuple(weakref.ref(a) for a in arrays)

    def _insert(self, key: tuple, res: QueryResult) -> None:
        with self._lock:
            self._lru[key] = res
            self._lru.move_to_end(key)
            while len(self._lru) > self.capacity:
                self._lru.popitem(last=False)
                self.evictions += 1
                self._m_evictions.inc()
            self._m_size.set(len(self._lru))

    def lookup_only(self, rt, users, row, *, k, c, delta=None,
                    record_miss: bool = True):
        """LRU probe WITHOUT dispatch: the cached per-query QueryResult
        if this exact (query, k, c) is live for the CURRENT index
        generation, else None. Never touches the inner backend. Two
        callers (repro.serve): the cache-only degrade rung 3, and the
        scheduler's ADMISSION path (PR 10 — a hit resolves at submit and
        never occupies a tick slot). The admission path passes
        `record_miss=False`: its misses go on to dispatch through
        `query_batch`, which counts them — double-counting would skew the
        hit-rate dashboards."""
        with self._lock:
            self._check_epoch(rt, users, delta)
            key = (self._key_bytes(np.asarray(row)), int(k), float(c))
            cached = self._lru.get(key)
            if cached is None:
                if record_miss:
                    self.misses += 1
                    self._m_misses.inc()
                return None
            self._lru.move_to_end(key)
            self.hits += 1
        self._m_hits.inc()
        return cached

    # -------------------------------------------------------------- query
    def _lookup_batch(self, rt, users, rows, *, k, c, delta):
        """Shared lookup phase over HOST query rows: per-row LRU probe
        under the lock. Returns (keys, per_query, miss_order) — the
        dispatch entries (`query_batch`, `dispatch_device`) execute the
        deduped miss block their own way and hand the result to
        `_finish_batch`."""
        with trace.span("cache.lookup", batch=rows.shape[0]) as sp:
            with self._lock:
                self._check_epoch(rt, users, delta)
                keys = [(self._key_bytes(rows[i]), int(k), float(c))
                        for i in range(rows.shape[0])]

                per_query: list = [None] * len(keys)
                miss_order: "OrderedDict[tuple, int]" = OrderedDict()
                for i, key in enumerate(keys):
                    cached = self._lru.get(key)
                    if cached is not None:
                        self._lru.move_to_end(key)
                        per_query[i] = cached
                        self.hits += 1
                    else:
                        miss_order.setdefault(key, i)  # dedupe: first seen
                        self.misses += 1
            n_miss = len(keys) - sum(r is not None for r in per_query)
            sp.set(hits=len(keys) - n_miss, misses=n_miss)
        self._m_hits.inc(len(keys) - n_miss)
        self._m_misses.inc(n_miss)
        return keys, per_query, miss_order

    def _finish_batch(self, keys, per_query, miss_order, res):
        """Insert the miss block's per-query slices and assemble the
        tick's stacked QueryResult (tick-local results survive assembly
        even when the LRU is smaller than the tick's own unique-miss
        count)."""
        if miss_order:
            fresh = {}
            for j, key in enumerate(miss_order):
                one = jax.tree_util.tree_map(lambda x, j=j: x[j], res)
                fresh[key] = one
                self._insert(key, one)
            for i, key in enumerate(keys):
                if per_query[i] is None:
                    per_query[i] = fresh[key]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_query)

    def query_batch(self, rt, users, qs, *, k, c, delta=None):
        rows = np.asarray(jax.device_get(qs))
        keys, per_query, miss_order = self._lookup_batch(
            rt, users, rows, k=k, c=c, delta=delta)
        res = None
        if miss_order:
            idx = list(miss_order.values())
            block = qs[jnp.asarray(idx)]
            if len(idx) < _MIN_DISPATCH <= len(keys):
                block = jnp.concatenate([block, block[-1:]])
            # omit the delta kwarg on the static path (mirrors
            # engine.query_batch_at): pre-PR-3 custom inner backends with
            # a (rt, users, qs, *, k, c) signature keep working when
            # wrapped, as long as the engine is never mutated
            if delta is None:
                res = self.inner.query_batch(rt, users, block, k=k, c=c)
            else:
                res = self.inner.query_batch(rt, users, block, k=k, c=c,
                                             delta=delta)
        return self._finish_batch(keys, per_query, miss_order, res)

    def dispatch_device(self, rt, users, qs, *, k, c, delta=None):
        """Serving entry (PR 10): HOST query rows in, device handles out.
        Keying needs host bytes — exactly what the scheduler now keeps —
        so the lookup pays zero transfers; only the deduped MISS block is
        gathered host-side and staged by the inner `dispatch_device`'s
        single H2D. Hits are device-resident cached per-query results, so
        the assembled stack is device handles either way, with no host
        sync on this path. Values are bit-identical to `query_batch`
        (same miss block bytes, same inner computation)."""
        rows = np.asarray(jax.device_get(qs))   # no-op for host arrays
        keys, per_query, miss_order = self._lookup_batch(
            rt, users, rows, k=k, c=c, delta=delta)
        res = None
        if miss_order:
            idx = list(miss_order.values())
            block = rows[idx]
            if len(idx) < _MIN_DISPATCH <= len(keys):
                block = np.concatenate([block, block[-1:]])
            res = self.inner.dispatch_device(rt, users, block, k=k, c=c,
                                             delta=delta)
        return self._finish_batch(keys, per_query, miss_order, res)


@BK.register_wrapper("cached")
def _make_cached(inner: str, *, mesh=None) -> CachingBackend:
    """Registry hook: `get_backend("cached:<inner>")` lands here."""
    return CachingBackend(inner, mesh=mesh)
