"""Certified degrade ladder: trade answer tightness for survival under
sustained overload, one explicit rung at a time (PR 9).

Under offered load past the back-pressure knee the scheduler can only
shed (QueueFull) or queue (unbounded latency). The ladder adds a third
option: keep admitting, but serve CHEAPER — and because every rung still
returns certified (r↓, r↑) bounds, the c-approximation contract is
RELAXED EXPLICITLY (the caller can read the served contract off the tick
record and the `serve_degrade_level` gauge), never silently violated.

The rungs (level 0 = normal; each adds to the previous):

  0  normal serving — the configured backend, the submitted c.
  1  backend degrade hook — `QueryBackend.degrade(1)`: the pruned backend
     disables its `max_union_frac` dense-fallback, so a poorly-pruning
     query pays the certified two-phase scan over its kept blocks instead
     of a full-scan latency spike (bimodal p99 is what kills deadline
     SLOs under load). Bounds are unchanged — this rung is free of
     contract cost.
  2  widen the effective approximation: dispatch at c_eff = c · widen_c.
     A looser c admits more of the already-certified candidate set, so
     selection does strictly less bound-tightening work; the result is a
     VALID c_eff-approximation with valid bounds, reported as such
     (`TickStats.degrade_level`, and the auditor is told c_eff so its
     accuracy gauge judges the relaxed contract actually served).
  3  cache-only: answer LRU hits (exact results computed earlier this
     epoch — their certified bounds are as valid as at first compute) and
     SHED misses with `QueueFull` (reason label "degraded"). Requires a
     `CachingBackend` anywhere in the engine's wrapper chain; without one
     the ladder tops out at rung 2.

Hysteresis: stepping reacts to the queue depth observed at each tick cut
against high/low watermarks, and a step (either direction) needs
`dwell_ticks` CONSECUTIVE over/under-watermark ticks — a single bursty
tick cannot thrash the ladder, and recovery (step-up) is as deliberate
as degradation. The current level is exported on the
`serve_degrade_level` gauge.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs import registry as obs


@dataclasses.dataclass(frozen=True)
class DegradePolicy:
    """Watermarks + hysteresis for the ladder.

    high_depth:  queue depth at tick cut that counts as overloaded.
    low_depth:   depth that counts as recovered (must be < high_depth).
    dwell_ticks: consecutive over/under-watermark ticks required to step.
    max_level:   ladder ceiling (3 = cache-only; 2 when no cache exists).
    widen_c:     the rung-2 contract relaxation factor (c_eff = c · this).
    """

    high_depth: int = 32
    low_depth: int = 4
    dwell_ticks: int = 3
    max_level: int = 3
    widen_c: float = 1.5

    def __post_init__(self):
        if self.low_depth >= self.high_depth:
            raise ValueError(f"low_depth {self.low_depth} must be < "
                             f"high_depth {self.high_depth} (hysteresis)")
        if self.dwell_ticks < 1:
            raise ValueError("dwell_ticks must be >= 1")
        if not 0 <= self.max_level <= 3:
            raise ValueError("max_level must be in [0, 3]")
        if self.widen_c < 1.0:
            raise ValueError("widen_c must be >= 1.0 (a degrade rung "
                             "relaxes the contract, never tightens it)")


def find_cache(backend):
    """The first `CachingBackend` in a wrapper chain (walking `.inner`),
    or None — rung 3 needs its LRU."""
    from repro.serve.cache import CachingBackend
    bk = backend
    while bk is not None:
        if isinstance(bk, CachingBackend):
            return bk
        bk = getattr(bk, "inner", None)
    return None


class DegradeController:
    """Per-scheduler ladder state machine, driven at each tick cut.

    The controller owns the level; the scheduler asks `on_tick_cut(depth)`
    when it forms a tick and adapts its dispatch to the returned level.
    `backend` (optional) receives `degrade(level)` on every level change
    so rung 1 reaches execution; `cache` (optional, auto-discovered from
    the backend chain when omitted) enables rung 3.
    """

    def __init__(self, policy: DegradePolicy = None, *, backend=None,
                 cache=None, registry: Optional[obs.MetricsRegistry] = None):
        self.policy = policy if policy is not None else DegradePolicy()
        self.backend = backend
        self.cache = cache if cache is not None else find_cache(backend)
        self.level = 0
        self._hot = 0           # consecutive ticks at/above high_depth
        self._cool = 0          # consecutive ticks at/below low_depth
        self.transitions: list = []     # (level_from, level_to) history
        reg = registry if registry is not None else obs.get_default()
        self._m_level = reg.gauge(
            "serve_degrade_level",
            "current degrade-ladder rung (0 = normal serving)")
        self._m_steps = reg.counter(
            "serve_degrade_steps_total", "degrade-ladder level changes")
        self._m_level.set(0)

    @property
    def effective_max(self) -> int:
        """Rung 3 needs a cache; without one the ladder tops out at 2."""
        top = self.policy.max_level
        return min(top, 2) if self.cache is None else top

    def widened_c(self, c: float) -> float:
        """The contract actually served at the current level."""
        return c * self.policy.widen_c if self.level >= 2 else c

    def _set_level(self, level: int) -> None:
        if level == self.level:
            return
        self.transitions.append((self.level, level))
        self.level = level
        self._m_level.set(level)
        self._m_steps.inc()
        if self.backend is not None:
            # the backend hook is best-effort: a backend without degrade
            # support must not break the ladder for the scheduler rungs
            try:
                self.backend.degrade(level)
            except Exception:
                pass

    def on_tick_cut(self, depth: int) -> int:
        """Observe the queue depth at a tick cut; returns the level the
        tick must be dispatched at."""
        p = self.policy
        if depth >= p.high_depth:
            self._hot += 1
            self._cool = 0
            if self._hot >= p.dwell_ticks and self.level < self.effective_max:
                self._set_level(self.level + 1)
                self._hot = 0
        elif depth <= p.low_depth:
            self._cool += 1
            self._hot = 0
            if self._cool >= p.dwell_ticks and self.level > 0:
                self._set_level(self.level - 1)
                self._cool = 0
        else:
            # between watermarks: hold the level, reset both dwell counts
            # (the hysteresis band)
            self._hot = 0
            self._cool = 0
        return self.level

    def reset(self) -> None:
        """Back to normal serving (shutdown path)."""
        self._hot = self._cool = 0
        self._set_level(0)
