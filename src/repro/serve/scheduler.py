"""Async micro-batching request scheduler — single queries in, B-sized
`engine.query_batch` ticks out.

The paper's item-centric workload is served ONLINE: queries arrive one at
a time, but PR 1 made the B-query block the cheap unit of work (the
(n, τ) rank table and (n, d) user matrix are streamed once per block, not
once per query). `MicroBatcher` closes that gap: `submit(q, k, c)`
returns a Future immediately; a dispatcher thread coalesces queued
requests into ticks of up to `max_batch` queries and executes each tick
as ONE `engine.query_batch` call.

Latency-vs-throughput knob
--------------------------
A tick dispatches as soon as any (k, c) group reaches `max_batch` queued
requests, or `max_wait_ms` after the head request arrived — whichever
comes first. Small `max_wait_ms` bounds queueing latency at low offered
load (ticks go out nearly empty); large `max_wait_ms` trades latency for
fill ratio and table-bandwidth amortization (see
`benchmarks/perf_engine.py --serve` for the measured curve). Requests
with different (k, c) never share a tick — those are static arguments of
the compiled batch program — and a FULL group behind a straggler head
dispatches immediately rather than waiting out the head's deadline.

Partial-batch padding
---------------------
Partial ticks are EDGE-PADDED to the compiled `max_batch` shape
(`pad_block`), so every tick reuses one compiled XLA program instead of
retracing per fill level; pad rows are sliced off before the Futures
resolve. Padding is numerically invisible: a batched matmul's output
column (i, j) depends only on the user row i, query column j, and the
accumulation order — not on the other columns' VALUES — so the real
rows of a padded tick are bit-identical to dispatching the unpadded
block directly (asserted per backend in tests/test_serve.py). The one
platform caveat: a width-1 block lowers as a matvec with a different
accumulation order, so `pad_block` never emits width-1 dispatches and
bit-identity holds for every partial fill ≥ 2; a singleton tick is
padded like any other and agrees with direct execution on every
table-derived field (indices, r↓/r↑, R↓_k/R↑_k), with `est` equal to
float accuracy.

Back-pressure
-------------
`max_depth` bounds the queue: a `submit` that would push the queue past
it FAILS FAST with `QueueFull` instead of growing an unbounded backlog
(under sustained overload an unbounded queue turns finite latency into
infinite latency for everyone). Rejections are counted per tick
(`TickStats.rejected` — rejections observed since the previous tick) and
in aggregate (`ServeStats.rejected`, plus the queue-depth high-watermark)
so dashboards can see the overload knee; `benchmarks/perf_engine.py
--serve` sweeps offered load past capacity and reports the column.

Snapshot-pinned ticks
---------------------
When the engine is snapshot-versioned (`repro.index`: mutable engines
publish epoch-versioned `IndexSnapshot`s), every tick PINS one snapshot
(`engine.current_snapshot()`) and dispatches the whole batch against it
via `engine.query_batch_at`, recording the epoch in `TickStats.epoch`.
A concurrent mutation or rebuild hot-swap therefore lands BETWEEN ticks,
never inside one: all futures of a tick resolve against exactly one
index generation (asserted in tests/test_index.py). Engines without
snapshots dispatch through plain `engine.query_batch`.

Per-tick stats (`TickStats`) record queue depth at dispatch, fill ratio,
and per-request latency; `MicroBatcher.stats()` aggregates them into
p50/p99 latency for the serving dashboards.

Deadlines, reject reasons, degrade (PR 9)
-----------------------------------------
`submit(..., deadline_ms=)` attaches a latency budget: an already-expired
submit is rejected at admission, and every tick cut SWEEPS the queue
first, failing expired requests with `DeadlineExceeded` BEFORE they
occupy a tick slot (a request that cannot possibly meet its deadline
must not displace one that can). Every rejection carries a reason label
on the `serve_rejected_total{reason=...}` registry counter: `queue_full`
(max_depth back-pressure), `deadline` (expired at admission or in the
sweep), `shutdown` (submit after close, or queue shed past the bounded
drain of `close(drain_s=...)`), and `degraded` (cache-only rung misses,
see below). `submit` after `close()` raises the typed `SchedulerClosed`
instead of hanging, and `close()` is idempotent.

Passing `degrade=DegradeController(...)` (repro.serve.degrade) arms the
certified degrade ladder: the controller observes queue depth at every
tick cut and the tick dispatches at its current rung — rung 2 widens the
served contract to c_eff = c · widen_c (still a certified
c_eff-approximation, recorded in `TickStats.degrade_level` and audited
at c_eff), rung 3 serves LRU hits only and sheds misses. Fault-injection
sites `serve.dispatch` / `serve.slow_tick` (repro.serve.faults) live at
the top of the dispatch path, one flag check when disabled.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Deque, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import registry as obs
from repro.obs import trace
from repro.serve import faults


def pad_block(qs: jax.Array, max_batch: int) -> jax.Array:
    """Edge-pad a (B, d) query block to the compiled (max_batch, d) shape.

    Pad rows repeat the last real query: their columns are well-defined on
    every backend and are masked out of results by slicing. B = 0 or
    B > max_batch are caller errors, and so is max_batch < 2: the padded
    width is the dispatch width, and a width-1 dispatch lowers as a
    matvec with a different accumulation order — the exact case the
    module's "dispatches never shrink below width 2" bit-identity
    invariant (module doc) exists to rule out.
    """
    if max_batch < 2:
        raise ValueError(f"max_batch must be >= 2 (width-1 dispatches "
                         f"lower as a matvec and break partial-tick "
                         f"bit-identity); got {max_batch}")
    b = qs.shape[0]
    if not 1 <= b <= max_batch:
        raise ValueError(f"block of {b} queries does not fit max_batch="
                         f"{max_batch}")
    if b == max_batch:
        return qs
    return jnp.concatenate(
        [qs, jnp.broadcast_to(qs[-1:], (max_batch - b, qs.shape[1]))])


def _program_count() -> int:
    """Compiled-program count across the query stack (0 if unavailable).

    Deferred import: the counter lives with the elastic backend
    (`repro.core.elastic.compiled_program_count`), whose module this one
    must not import at load time (serve ↔ core layering)."""
    try:
        from repro.core.elastic import compiled_program_count
        return compiled_program_count()
    except Exception:
        return 0


class QueueFull(RuntimeError):
    """`submit` rejected: the queue is at `max_depth` (back-pressure)."""


class SchedulerClosed(RuntimeError):
    """`submit` after `close()`: the scheduler is shut down (reject
    reason `shutdown`). A RuntimeError subclass so pre-PR-9 callers
    catching the old untyped close error keep working."""


class DeadlineExceeded(RuntimeError):
    """The request's `deadline_ms` budget expired before dispatch —
    at admission, in the per-tick queue sweep, or as a queued casualty
    of a bounded drain (reject reason `deadline`)."""

# Reject-reason label values on serve_rejected_total{reason=...}; the
# catalog is closed so dashboards can enumerate it.
REJECT_REASONS = ("queue_full", "deadline", "shutdown", "degraded")


@dataclasses.dataclass(frozen=True)
class TickStats:
    """One dispatched tick, as observed by the scheduler."""

    batch: int                 # real (unpadded) queries in the tick
    queue_depth: int           # queue length when the tick was formed
    fill_ratio: float          # batch / max_batch
    wait_ms: float             # head request's submit → dispatch wait
    latencies_ms: Tuple[float, ...]   # per-request submit → resolve
    rejected: int = 0          # submits rejected since the previous tick
    epoch: Optional[int] = None  # pinned index epoch (snapshot engines)
    # Query-stack XLA programs compiled DURING this tick's dispatch
    # (repro.core.elastic.compiled_program_count delta). Nonzero only on
    # warm-up ticks; a nonzero value on a steady-state tick is the
    # recompile-storm signature the elastic backend exists to kill, and
    # exactly what its p99 spike looks like to a dashboard.
    compiles: int = 0
    # Deadline sweeps attributed to this tick's cut (expired requests
    # shed from the queue before the tick was formed), and the degrade
    # rung the tick was dispatched at (0 = normal; repro.serve.degrade).
    expired: int = 0
    degrade_level: int = 0
    # A terminal record (batch == 0) is flushed at close() when rejects
    # arrived after the last dispatched tick — every rejection is
    # attributed to exactly one TickStats.


@dataclasses.dataclass(frozen=True)
class ServeStats:
    """Aggregate over a MicroBatcher's lifetime (see `stats()`)."""

    ticks: int
    requests: int
    mean_fill: float
    mean_queue_depth: float
    p50_ms: float
    p99_ms: float
    rejected: int = 0          # submits rejected by the max_depth bound
    depth_hwm: int = 0         # queue-depth high-watermark
    expired: int = 0           # requests shed by deadline (admission+sweep)

    def __str__(self):
        return (f"{self.requests} reqs / {self.ticks} ticks  "
                f"fill {self.mean_fill:.2f}  depth {self.mean_queue_depth:.1f}"
                f" (hwm {self.depth_hwm})  rej {self.rejected}"
                f"  exp {self.expired}"
                f"  p50 {self.p50_ms:.2f} ms  p99 {self.p99_ms:.2f} ms")


class _Request:
    __slots__ = ("q", "k", "c", "future", "t_submit", "t_deadline")

    def __init__(self, q, k, c, deadline_ms=None):
        self.q = q
        self.k = int(k)
        self.c = float(c)
        self.future: Future = Future()
        self.t_submit = time.monotonic()
        # absolute monotonic deadline; None = no latency budget
        self.t_deadline = (None if deadline_ms is None
                           else self.t_submit + float(deadline_ms) / 1e3)

    @property
    def key(self):
        return (self.k, self.c)


class MicroBatcher:
    """Coalesce async single-query submissions into `query_batch` ticks.

    Usage::

        with MicroBatcher(eng, max_batch=16, max_wait_ms=2.0) as mb:
            futs = [mb.submit(q, k=10, c=2.0) for q in queries]
            results = [f.result() for f in futs]     # QueryResult each
            print(mb.stats())

    Thread-safe; one background dispatcher thread. `close()` (or leaving
    the context) drains the queue before the thread exits, so every
    accepted Future resolves.
    """

    def __init__(self, engine, *, max_batch: int = 16,
                 max_wait_ms: float = 2.0, max_depth: Optional[int] = None,
                 auditor=None, degrade=None):
        # Width 1 is rejected, not padded around: the module's partial-tick
        # bit-identity argument needs every dispatch ≥ 2 wide (matvec
        # lowering caveat, module doc), and a max_batch=1 scheduler could
        # never form a wider tick.
        if max_batch < 2:
            raise ValueError(f"max_batch must be >= 2 (width-1 dispatches "
                             f"lower as a matvec and break partial-tick "
                             f"bit-identity), got {max_batch}")
        if max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, got {max_wait_ms}")
        if max_depth is not None and max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.engine = engine
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self.max_depth = None if max_depth is None else int(max_depth)
        # Optional shadow auditor (repro.obs.audit.QualityAuditor): every
        # resolved request is OFFERED to it with the pinned snapshot; the
        # auditor samples and re-scores off-thread, never blocking ticks.
        self.auditor = auditor
        # Optional degrade-ladder controller (repro.serve.degrade): asked
        # for the current rung at every tick cut; None = always rung 0.
        self.degrade = degrade
        reg = obs.get_default()
        self._m_submitted = reg.counter(
            "serve_requests_total", "requests accepted by submit()")
        self._m_rejected = reg.counter(
            "serve_rejected_total", "submits rejected by back-pressure")
        # Per-reason reject counters (same metric name, a `reason` label
        # per REJECT_REASONS value; the unlabelled aggregate above stays
        # for pre-PR-9 dashboards).
        self._m_reject_reason = {
            reason: reg.counter(
                "serve_rejected_total", "rejects by reason",
                labels={"reason": reason})
            for reason in REJECT_REASONS}
        self._m_ticks = reg.counter(
            "serve_ticks_total", "dispatched micro-batch ticks")
        self._m_compiles = reg.counter(
            "serve_compiles_total", "XLA programs compiled during ticks")
        self._m_depth = reg.gauge(
            "serve_queue_depth", "queue length at the last tick cut")
        self._m_fill = reg.gauge(
            "serve_tick_fill_ratio", "fill ratio of the last tick")
        self._m_latency = reg.histogram(
            "serve_request_latency_ms", "submit → resolve latency")
        self._m_wait = reg.histogram(
            "serve_queue_wait_ms", "submit → dispatch queue wait")
        self._queue: Deque[_Request] = deque()
        self._cond = threading.Condition()
        self._stop = False
        self._closed = False        # close() already ran (idempotency)
        self._drain_deadline = None  # monotonic bound on close() draining
        self._flush = False
        self._busy = False          # a tick is being dispatched right now
        self._ticks: List[TickStats] = []
        self._rejected_total = 0
        self._rejected_since_tick = 0
        self._expired_total = 0
        self._expired_since_tick = 0
        self._depth_hwm = 0
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="microbatcher")
        self._thread.start()

    # ------------------------------------------------------------- client
    def submit(self, q: jax.Array, k: int, c: float,
               deadline_ms: Optional[float] = None) -> Future:
        """Enqueue one (d,) query; resolves to its per-query QueryResult
        with HOST (numpy) leaves, leading batch axis already squeezed —
        serving results are client-bound, so the tick is transferred once
        and split into zero-copy row views.

        With `max_depth` set, a submit that finds the queue at the bound
        raises `QueueFull` immediately (fail-fast back-pressure) instead
        of accepting work the scheduler cannot keep up with.

        `deadline_ms` attaches a latency budget relative to NOW: a
        non-positive budget is rejected at admission with
        `DeadlineExceeded`, and a queued request whose budget expires
        before its tick is cut is failed by the per-tick sweep (its
        Future raises `DeadlineExceeded`). After `close()`, submits
        raise `SchedulerClosed` (reject reason `shutdown`)."""
        q = jnp.asarray(q)
        if q.ndim != 1:
            raise ValueError(f"submit expects a (d,) query; got {q.shape}")
        if deadline_ms is not None and deadline_ms <= 0:
            # already expired at admission: shed before it can take a
            # queue slot, let alone a tick slot
            with self._cond:
                self._expired_total += 1
                self._expired_since_tick += 1
            self._m_reject_reason["deadline"].inc()
            raise DeadlineExceeded(
                f"deadline_ms={deadline_ms} already expired at submit")
        req = _Request(q, k, c, deadline_ms=deadline_ms)
        with self._cond:
            if self._stop:
                # Not counted into _rejected_total: the dispatcher has
                # (or will have) exited, so no terminal TickStats could
                # attribute it — the labelled counter is the record.
                self._m_reject_reason["shutdown"].inc()
                raise SchedulerClosed("MicroBatcher is closed")
            if (self.max_depth is not None
                    and len(self._queue) >= self.max_depth):
                self._rejected_total += 1
                self._rejected_since_tick += 1
                self._m_rejected.inc()
                self._m_reject_reason["queue_full"].inc()
                raise QueueFull(
                    f"queue at max_depth={self.max_depth}; request rejected "
                    "(fail-fast back-pressure — retry with backoff)")
            self._queue.append(req)
            self._depth_hwm = max(self._depth_hwm, len(self._queue))
            self._cond.notify_all()
        self._m_submitted.inc()
        return req.future

    def flush(self) -> None:
        """Dispatch everything queued without waiting out `max_wait_ms`,
        and block until all accepted requests have resolved."""
        with self._cond:
            self._flush = True
            self._cond.notify_all()
            while self._queue or self._busy:
                self._cond.wait(timeout=0.05)
            self._flush = False

    def close(self, drain_s: Optional[float] = None) -> None:
        """Drain the queue, then stop the dispatcher thread. Idempotent —
        a second close() returns immediately.

        `drain_s` bounds the drain: queued requests still undispatched
        when the budget runs out are shed (`SchedulerClosed`, reject
        reason `shutdown`) instead of holding up shutdown behind a slow
        engine. The default None drains fully, as before."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._stop = True
            if drain_s is not None:
                self._drain_deadline = time.monotonic() + float(drain_s)
            self._cond.notify_all()
        self._thread.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def stats(self) -> ServeStats:
        """Aggregate tick statistics (p50/p99 over request latencies)."""
        with self._cond:            # one atomic snapshot of ticks+counters
            ticks = list(self._ticks)
            rejected, hwm = self._rejected_total, self._depth_hwm
            expired = self._expired_total
        if not ticks:
            return ServeStats(0, 0, 0.0, 0.0, 0.0, 0.0, rejected=rejected,
                              depth_hwm=hwm, expired=expired)
        # The terminal rejection record (batch == 0, no latencies) is an
        # accounting tick: it carries rejects into the aggregate but must
        # not skew the dispatch-shape means or crash the percentiles.
        dispatched = [t for t in ticks if t.batch > 0]
        lats = np.concatenate(
            [np.asarray(t.latencies_ms, dtype=float) for t in ticks])
        return ServeStats(
            ticks=len(ticks),
            requests=int(lats.size),
            mean_fill=(float(np.mean([t.fill_ratio for t in dispatched]))
                       if dispatched else 0.0),
            mean_queue_depth=(
                float(np.mean([t.queue_depth for t in dispatched]))
                if dispatched else 0.0),
            p50_ms=float(np.percentile(lats, 50)) if lats.size else 0.0,
            p99_ms=float(np.percentile(lats, 99)) if lats.size else 0.0,
            rejected=rejected,
            depth_hwm=hwm,
            expired=expired,
        )

    @property
    def tick_log(self) -> List[TickStats]:
        with self._cond:
            return list(self._ticks)

    # --------------------------------------------------------- dispatcher
    def _full_key(self):
        """The (k, c) of the first group to reach `max_batch` queued
        requests, or None. Requests with different static args cannot
        share a tick (k/c are compiled into the batch program), but a
        FULL group behind a lone straggler head is dispatchable NOW —
        waiting out the head's deadline would be head-of-line blocking."""
        counts: dict = {}
        for r in self._queue:
            counts[r.key] = counts.get(r.key, 0) + 1
            if counts[r.key] >= self.max_batch:
                return r.key
        return None

    def _sweep_expired(self, now: float) -> List[_Request]:
        """Remove deadline-expired requests from the queue (lock held).
        Returns the shed requests — their futures are failed OUTSIDE the
        lock (`_fail_expired`), so a future callback can never deadlock
        against the scheduler."""
        if not any(r.t_deadline is not None and now >= r.t_deadline
                   for r in self._queue):
            return []
        keep: Deque[_Request] = deque()
        dead: List[_Request] = []
        for r in self._queue:
            if r.t_deadline is not None and now >= r.t_deadline:
                dead.append(r)
            else:
                keep.append(r)
        self._queue = keep
        self._expired_total += len(dead)
        self._expired_since_tick += len(dead)
        return dead

    def _fail_expired(self, reqs: List[_Request]) -> None:
        for r in reqs:
            self._m_reject_reason["deadline"].inc()
            if not r.future.cancelled():
                r.future.set_exception(DeadlineExceeded(
                    "deadline expired before dispatch (per-tick sweep)"))

    def _fail_drained(self, reqs: List[_Request]) -> None:
        for r in reqs:
            self._m_reject_reason["shutdown"].inc()
            if not r.future.cancelled():
                r.future.set_exception(SchedulerClosed(
                    "scheduler closed before dispatch (bounded drain)"))

    def _loop(self):
        while True:
            expired: List[_Request] = []
            drained: List[_Request] = []
            reqs = None
            terminal = False
            with self._cond:
                while not self._queue and not self._stop:
                    self._cond.wait()
                now = time.monotonic()
                # Deadline sweep FIRST: an expired request must not be
                # chosen as the head nor occupy a tick slot.
                expired += self._sweep_expired(now)
                if (self._stop and self._queue
                        and self._drain_deadline is not None
                        and now >= self._drain_deadline):
                    # bounded drain exhausted: shed the remainder so
                    # close(drain_s=...) returns promptly; the sheds flow
                    # into the terminal accounting record below
                    drained = list(self._queue)
                    self._queue.clear()
                    self._rejected_total += len(drained)
                    self._rejected_since_tick += len(drained)
                if not self._queue:
                    if self._stop:      # stop requested, queue drained
                        # Rejects/expiries that arrived AFTER the last
                        # tick was cut would otherwise vanish (they are
                        # only read at the next cut, and there is no next
                        # cut): flush them into a terminal accounting
                        # record so ServeStats and tick_log stay complete
                        # under close().
                        tail = self._rejected_since_tick
                        self._rejected_since_tick = 0
                        tail_exp = self._expired_since_tick
                        self._expired_since_tick = 0
                        if tail or tail_exp:
                            self._ticks.append(TickStats(
                                batch=0, queue_depth=0, fill_ratio=0.0,
                                wait_ms=0.0, latencies_ms=(),
                                rejected=tail, expired=tail_exp))
                        terminal = True
                    # else: the sweep emptied the queue mid-serve — fail
                    # the shed futures below and go back to waiting
                else:
                    head = self._queue[0]
                    deadline = head.t_submit + self.max_wait_ms / 1e3
                    while (self._full_key() is None
                           and not (self._stop or self._flush)):
                        remaining = deadline - time.monotonic()
                        if remaining <= 0:
                            break
                        self._cond.wait(timeout=remaining)
                    # late sweep: a request whose budget ran out DURING
                    # the coalescing wait must not take a tick slot
                    expired += self._sweep_expired(time.monotonic())
                    if self._queue:
                        # a full group anywhere in the queue outranks the
                        # partial head tick; the head still dispatches by
                        # its deadline
                        key = self._full_key() or self._queue[0].key
                        reqs, rest = [], deque()
                        while self._queue:
                            r = self._queue.popleft()
                            if r.key == key and len(reqs) < self.max_batch:
                                reqs.append(r)
                            else:
                                rest.append(r)
                        depth = len(reqs) + len(rest)
                        self._queue = rest
                        rejected = self._rejected_since_tick
                        self._rejected_since_tick = 0
                        n_expired = self._expired_since_tick
                        self._expired_since_tick = 0
                        # degrade rung for this tick, from the queue
                        # depth observed at the cut (hysteresis inside
                        # the controller — repro.serve.degrade)
                        level = (self.degrade.on_tick_cut(depth)
                                 if self.degrade is not None else 0)
                        self._busy = True
            if expired:
                self._fail_expired(expired)
            if drained:
                self._fail_drained(drained)
            if terminal:
                return
            if reqs is None:
                continue
            try:
                self._dispatch(reqs, depth, rejected, n_expired, level)
            finally:
                with self._cond:
                    self._busy = False
                    self._cond.notify_all()

    def _dispatch(self, reqs: List[_Request], depth: int, rejected: int = 0,
                  expired: int = 0, level: int = 0):
        t_dispatch = time.monotonic()
        k, c = reqs[0].key
        # rung 2+ of the degrade ladder dispatches at a WIDENED contract:
        # the result is a valid c_eff-approximation, reported as such
        # (TickStats.degrade_level) and audited at c_eff (module doc)
        c_eff = (self.degrade.widened_c(c)
                 if self.degrade is not None else c)
        if (level >= 3 and self.degrade is not None
                and self.degrade.cache is not None
                and getattr(self.engine, "current_snapshot", None)
                is not None):
            self._dispatch_cache_only(reqs, depth, rejected, expired,
                                      level, t_dispatch)
            return
        epoch = None
        snap = None
        programs_before = _program_count()
        sp = trace.span("serve.tick", batch=len(reqs), depth=depth, k=k)
        try:
            with sp:
                if faults.ACTIVE is not None:
                    faults.fire("serve.slow_tick")
                    faults.fire("serve.dispatch")
                if trace.is_enabled():
                    # retroactive cross-thread spans: each request's
                    # admission → dispatch queue wait, timed from its
                    # client-thread submit; inside the tick span so the
                    # records attribute to the tick that served them
                    for r in reqs:
                        trace.event("serve.queue_wait", r.t_submit,
                                    t_dispatch - r.t_submit, k=k)
                qs = pad_block(jnp.stack([r.q for r in reqs]),
                               self.max_batch)
                # Pin ONE index snapshot for the whole tick (module doc):
                # a hot-swap concurrent with this dispatch lands between
                # ticks, never inside one.
                snap_fn = getattr(self.engine, "current_snapshot", None)
                if snap_fn is not None:
                    snap = snap_fn()
                    epoch = getattr(snap, "epoch", None)
                    sp.set(epoch=epoch)
                    res = self.engine.query_batch_at(snap, qs, k=k, c=c_eff)
                else:
                    res = self.engine.query_batch(qs, k=k, c=c_eff)
                # One transfer for the whole tick: futures resolve to HOST
                # (numpy) QueryResults — per-request row views are
                # zero-copy, where B×fields device slices would dominate
                # the tick cost.
                host = jax.device_get(res)
        except Exception as e:                    # propagate to every caller
            for r in reqs:
                if not r.future.cancelled():
                    r.future.set_exception(e)
            # This tick records no TickStats — re-credit the rejects and
            # expiries it was carrying so the NEXT cut (or the terminal
            # flush at close) attributes them instead of dropping them.
            with self._cond:
                self._rejected_since_tick += rejected
                self._expired_since_tick += expired
            return
        now = time.monotonic()
        tick = TickStats(
            batch=len(reqs), queue_depth=depth,
            fill_ratio=len(reqs) / self.max_batch,
            wait_ms=(t_dispatch - reqs[0].t_submit) * 1e3,
            latencies_ms=tuple((now - r.t_submit) * 1e3 for r in reqs),
            rejected=rejected, epoch=epoch,
            compiles=max(0, _program_count() - programs_before),
            expired=expired, degrade_level=level)
        # Record the tick BEFORE resolving futures: a client that wakes
        # from f.result() must already see it in stats()/tick_log.
        with self._cond:
            self._ticks.append(tick)
        self._m_ticks.inc()
        if tick.compiles:
            self._m_compiles.inc(tick.compiles)
        self._m_depth.set(depth)
        self._m_fill.set(tick.fill_ratio)
        for r in reqs:
            self._m_wait.observe((t_dispatch - r.t_submit) * 1e3)
            self._m_latency.observe((now - r.t_submit) * 1e3)
        for i, r in enumerate(reqs):              # pad rows masked out here
            per_q = jax.tree_util.tree_map(lambda x, i=i: x[i], host)
            if not r.future.cancelled():
                r.future.set_result(per_q)
            if self.auditor is not None:
                # audited at the contract actually served (c_eff on
                # degraded ticks) — the accuracy gauge judges the
                # relaxed, REPORTED contract, not the requested one
                self.auditor.observe(np.asarray(r.q), per_q, k=k, c=c_eff,
                                     snapshot=snap)

    def _dispatch_cache_only(self, reqs: List[_Request], depth: int,
                             rejected: int, expired: int, level: int,
                             t_dispatch: float):
        """Degrade rung 3: answer LRU hits against the pinned snapshot,
        shed misses with `QueueFull` (reject reason `degraded`).

        A hit is an exact per-query result computed earlier in the SAME
        index generation — the cache invalidates on any snapshot change
        (`CachingBackend._check_epoch`), so its certified (r↓, r↑) bounds
        are as valid as at first compute. Misses shed instead of
        dispatching: rung 3 exists to take the rank table out of the
        serving path entirely."""
        cache = self.degrade.cache
        k, c = reqs[0].key
        c_eff = self.degrade.widened_c(c)
        snap = self.engine.current_snapshot()
        epoch = getattr(snap, "epoch", None)
        rt, users, delta = snap.rank_table, snap.query_users(), snap.corr
        hits: List[Tuple[_Request, object, float]] = []
        misses: List[_Request] = []
        with trace.span("serve.cache_only", batch=len(reqs), depth=depth,
                        k=k, epoch=epoch, level=level):
            for r in reqs:
                row = np.asarray(jax.device_get(r.q))
                # entries may have been cached at the base contract or at
                # the rung-2 widened one — a hit at either serves
                res, c_hit = None, c
                for c_try in ((c, c_eff) if c_eff != c else (c,)):
                    res = cache.lookup_only(rt, users, row, k=k, c=c_try,
                                            delta=delta)
                    if res is not None:
                        c_hit = c_try
                        break
                if res is None:
                    misses.append(r)
                else:
                    hits.append((r, jax.device_get(res), c_hit))
        with self._cond:
            self._rejected_total += len(misses)
        now = time.monotonic()
        tick = TickStats(
            batch=len(hits), queue_depth=depth,
            fill_ratio=len(hits) / self.max_batch,
            wait_ms=(t_dispatch - reqs[0].t_submit) * 1e3,
            latencies_ms=tuple((now - r.t_submit) * 1e3
                               for r, _, _ in hits),
            rejected=rejected + len(misses), epoch=epoch,
            expired=expired, degrade_level=level)
        with self._cond:
            self._ticks.append(tick)
        self._m_ticks.inc()
        self._m_depth.set(depth)
        self._m_fill.set(tick.fill_ratio)
        if misses:
            self._m_rejected.inc(len(misses))
            self._m_reject_reason["degraded"].inc(len(misses))
        for r in misses:
            if not r.future.cancelled():
                r.future.set_exception(QueueFull(
                    "shed at degrade level 3 (cache-only serving): "
                    "no cached result for this query"))
        for r, host, c_hit in hits:
            self._m_wait.observe((t_dispatch - r.t_submit) * 1e3)
            self._m_latency.observe((now - r.t_submit) * 1e3)
            if not r.future.cancelled():
                r.future.set_result(host)
            if self.auditor is not None:
                self.auditor.observe(np.asarray(r.q), host, k=k, c=c_hit,
                                     snapshot=snap)
