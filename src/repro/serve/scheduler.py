"""Async micro-batching request scheduler — single queries in, B-sized
`engine.query_batch` ticks out.

The paper's item-centric workload is served ONLINE: queries arrive one at
a time, but PR 1 made the B-query block the cheap unit of work (the
(n, τ) rank table and (n, d) user matrix are streamed once per block, not
once per query). `MicroBatcher` closes that gap: `submit(q, k, c)`
returns a Future immediately; a dispatcher thread coalesces queued
requests into ticks of up to `max_batch` queries and executes each tick
as ONE `engine.query_batch` call.

Latency-vs-throughput knob
--------------------------
A tick dispatches as soon as any (k, c) group reaches `max_batch` queued
requests, or `max_wait_ms` after the head request arrived — whichever
comes first. Small `max_wait_ms` bounds queueing latency at low offered
load (ticks go out nearly empty); large `max_wait_ms` trades latency for
fill ratio and table-bandwidth amortization (see
`benchmarks/perf_engine.py --serve` for the measured curve). Requests
with different (k, c) never share a tick — those are static arguments of
the compiled batch program — and a FULL group behind a straggler head
dispatches immediately rather than waiting out the head's deadline.

Partial-batch padding
---------------------
Partial ticks are EDGE-PADDED to the compiled `max_batch` shape
(`pad_block`), so every tick reuses one compiled XLA program instead of
retracing per fill level; pad rows are sliced off before the Futures
resolve. Padding is numerically invisible: a batched matmul's output
column (i, j) depends only on the user row i, query column j, and the
accumulation order — not on the other columns' VALUES — so the real
rows of a padded tick are bit-identical to dispatching the unpadded
block directly (asserted per backend in tests/test_serve.py). The one
platform caveat: a width-1 block lowers as a matvec with a different
accumulation order, so `pad_block` never emits width-1 dispatches and
bit-identity holds for every partial fill ≥ 2; a singleton tick is
padded like any other and agrees with direct execution on every
table-derived field (indices, r↓/r↑, R↓_k/R↑_k), with `est` equal to
float accuracy.

Back-pressure
-------------
`max_depth` bounds the queue: a `submit` that would push the queue past
it FAILS FAST with `QueueFull` instead of growing an unbounded backlog
(under sustained overload an unbounded queue turns finite latency into
infinite latency for everyone). Rejections are counted per tick
(`TickStats.rejected` — rejections observed since the previous tick) and
in aggregate (`ServeStats.rejected`, plus the queue-depth high-watermark)
so dashboards can see the overload knee; `benchmarks/perf_engine.py
--serve` sweeps offered load past capacity and reports the column.

Snapshot-pinned ticks
---------------------
When the engine is snapshot-versioned (`repro.index`: mutable engines
publish epoch-versioned `IndexSnapshot`s), every tick PINS one snapshot
(`engine.current_snapshot()`) and dispatches the whole batch against it
via `engine.query_batch_at`, recording the epoch in `TickStats.epoch`.
A concurrent mutation or rebuild hot-swap therefore lands BETWEEN ticks,
never inside one: all futures of a tick resolve against exactly one
index generation (asserted in tests/test_index.py). Engines without
snapshots dispatch through plain `engine.query_batch`.

Per-tick stats (`TickStats`) record queue depth at dispatch, fill ratio,
and per-request latency; `MicroBatcher.stats()` aggregates them into
p50/p99 latency for the serving dashboards.

Deadlines, reject reasons, degrade (PR 9)
-----------------------------------------
`submit(..., deadline_ms=)` attaches a latency budget: an already-expired
submit is rejected at admission, and every tick cut SWEEPS the queue
first, failing expired requests with `DeadlineExceeded` BEFORE they
occupy a tick slot (a request that cannot possibly meet its deadline
must not displace one that can). Every rejection carries a reason label
on the `serve_rejected_total{reason=...}` registry counter: `queue_full`
(max_depth back-pressure), `deadline` (expired at admission or in the
sweep), `shutdown` (submit after close, or queue shed past the bounded
drain of `close(drain_s=...)`), and `degraded` (cache-only rung misses,
see below). `submit` after `close()` raises the typed `SchedulerClosed`
instead of hanging, and `close()` is idempotent.

Passing `degrade=DegradeController(...)` (repro.serve.degrade) arms the
certified degrade ladder: the controller observes queue depth at every
tick cut and the tick dispatches at its current rung — rung 2 widens the
served contract to c_eff = c · widen_c (still a certified
c_eff-approximation, recorded in `TickStats.degrade_level` and audited
at c_eff), rung 3 serves LRU hits only and sheds misses. Fault-injection
sites `serve.dispatch` / `serve.slow_tick` / `serve.transfer`
(repro.serve.faults) live at the top of the dispatch and completion
paths, one flag check each when disabled.

Overlapped pipeline (PR 10)
---------------------------
The hot path is DOUBLE-BUFFERED: a dispatch stage (the dispatcher
thread) and a completion stage (a second thread) connected by a bounded
in-flight queue of ≤ `pipeline_depth` ticks. JAX dispatch is async — the
engine call returns unmaterialized device arrays immediately — so the
old stop-and-wait loop (`device_get` inline in the dispatch path) left
the accelerator idle for the whole host side of every tick: D2H readback,
per-request view splitting, future resolution, stats. Now the dispatcher
cuts and dispatches tick t+1 while tick t's device work is still in
flight; the completion stage performs each tick's SINGLE blocking D2H
(`serve.transfer` span) off the dispatch path and resolves futures from
there, in dispatch (FIFO) order.

Data stays device-resident end-to-end: `submit` keeps queries as HOST
numpy (no per-request H2D), tick assembly stacks and edge-pads in numpy,
and the whole tick pays exactly one H2D through the engine's
`dispatch_batch_at` → backend `dispatch_device` entry (which on
accelerators donates the tick-private block buffer back to XLA). When
the engine's backend composes a `CachingBackend`, the LRU lookup is
folded into the ADMISSION path: a `submit` whose exact (query, k, c) is
cached for the live snapshot resolves immediately and never occupies a
queue or tick slot (`ServeStats.admission_hits`).

Results are BIT-IDENTICAL to synchronous dispatch — the pipeline moves
buffers and threads, never values — and every PR 9 invariant holds with
ticks in flight: a completion-stage failure (e.g. an injected
`serve.transfer` fault) fails exactly that tick's futures typed and
re-credits its reject/expiry attribution to the next cut or the terminal
flush; `close(drain_s=...)` bounds the drain with ≥ 1 tick in flight and
never tears a future; `pipeline_depth=1` degenerates to the synchronous
schedule (the A/B baseline `benchmarks/perf_engine.py --serve
--saturate` measures overlap against). `TickStats.inflight` records the
pipeline occupancy at each dispatch; `ServeStats.overlap_efficiency` is
the fraction of ticks that actually overlapped another.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Deque, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import registry as obs
from repro.obs import trace
from repro.serve import faults


def pad_block(qs: jax.Array, max_batch: int) -> jax.Array:
    """Edge-pad a (B, d) query block to the compiled (max_batch, d) shape.

    Pad rows repeat the last real query: their columns are well-defined on
    every backend and are masked out of results by slicing. B = 0 or
    B > max_batch are caller errors, and so is max_batch < 2: the padded
    width is the dispatch width, and a width-1 dispatch lowers as a
    matvec with a different accumulation order — the exact case the
    module's "dispatches never shrink below width 2" bit-identity
    invariant (module doc) exists to rule out.
    """
    if max_batch < 2:
        raise ValueError(f"max_batch must be >= 2 (width-1 dispatches "
                         f"lower as a matvec and break partial-tick "
                         f"bit-identity); got {max_batch}")
    b = qs.shape[0]
    if not 1 <= b <= max_batch:
        raise ValueError(f"block of {b} queries does not fit max_batch="
                         f"{max_batch}")
    if b == max_batch:
        return qs
    return jnp.concatenate(
        [qs, jnp.broadcast_to(qs[-1:], (max_batch - b, qs.shape[1]))])


def _program_count() -> int:
    """Compiled-program count across the query stack (0 if unavailable).

    Deferred import: the counter lives with the elastic backend
    (`repro.core.elastic.compiled_program_count`), whose module this one
    must not import at load time (serve ↔ core layering)."""
    try:
        from repro.core.elastic import compiled_program_count
        return compiled_program_count()
    except Exception:
        return 0


class QueueFull(RuntimeError):
    """`submit` rejected: the queue is at `max_depth` (back-pressure)."""


class SchedulerClosed(RuntimeError):
    """`submit` after `close()`: the scheduler is shut down (reject
    reason `shutdown`). A RuntimeError subclass so pre-PR-9 callers
    catching the old untyped close error keep working."""


class DeadlineExceeded(RuntimeError):
    """The request's `deadline_ms` budget expired before dispatch —
    at admission, in the per-tick queue sweep, or as a queued casualty
    of a bounded drain (reject reason `deadline`)."""

# Reject-reason label values on serve_rejected_total{reason=...}; the
# catalog is closed so dashboards can enumerate it.
REJECT_REASONS = ("queue_full", "deadline", "shutdown", "degraded")


@dataclasses.dataclass(frozen=True)
class TickStats:
    """One dispatched tick, as observed by the scheduler."""

    batch: int                 # real (unpadded) queries in the tick
    queue_depth: int           # queue length when the tick was formed
    fill_ratio: float          # batch / max_batch
    wait_ms: float             # head request's submit → dispatch wait
    latencies_ms: Tuple[float, ...]   # per-request submit → resolve
    rejected: int = 0          # submits rejected since the previous tick
    epoch: Optional[int] = None  # pinned index epoch (snapshot engines)
    # Query-stack XLA programs compiled DURING this tick's dispatch
    # (repro.core.elastic.compiled_program_count delta). Nonzero only on
    # warm-up ticks; a nonzero value on a steady-state tick is the
    # recompile-storm signature the elastic backend exists to kill, and
    # exactly what its p99 spike looks like to a dashboard.
    compiles: int = 0
    # Deadline sweeps attributed to this tick's cut (expired requests
    # shed from the queue before the tick was formed), and the degrade
    # rung the tick was dispatched at (0 = normal; repro.serve.degrade).
    expired: int = 0
    degrade_level: int = 0
    # A terminal record (batch == 0) is flushed at close() when rejects
    # arrived after the last dispatched tick — every rejection is
    # attributed to exactly one TickStats.
    # Pipeline observability (PR 10): the tick's single D2H readback
    # time in the completion stage, and the in-flight tick count at the
    # moment this tick was dispatched (self included — 1 means it did
    # not overlap anything; ≥ 2 is the pipelined steady state).
    transfer_ms: float = 0.0
    inflight: int = 1


@dataclasses.dataclass(frozen=True)
class ServeStats:
    """Aggregate over a MicroBatcher's lifetime (see `stats()`)."""

    ticks: int
    requests: int
    mean_fill: float
    mean_queue_depth: float
    p50_ms: float
    p99_ms: float
    rejected: int = 0          # submits rejected by the max_depth bound
    depth_hwm: int = 0         # queue-depth high-watermark
    expired: int = 0           # requests shed by deadline (admission+sweep)
    # PR 10: submits resolved from the LRU on the admission path (their
    # latencies are pooled into the percentiles; they occupy no tick),
    # and the fraction of dispatched ticks that overlapped ≥ 1 other
    # in-flight tick (the pipeline's utilization signal).
    admission_hits: int = 0
    overlap_efficiency: float = 0.0

    def __str__(self):
        return (f"{self.requests} reqs / {self.ticks} ticks  "
                f"fill {self.mean_fill:.2f}  depth {self.mean_queue_depth:.1f}"
                f" (hwm {self.depth_hwm})  rej {self.rejected}"
                f"  exp {self.expired}  adm {self.admission_hits}"
                f"  ovl {self.overlap_efficiency:.2f}"
                f"  p50 {self.p50_ms:.2f} ms  p99 {self.p99_ms:.2f} ms")


class _Request:
    __slots__ = ("q", "k", "c", "future", "t_submit", "t_deadline")

    def __init__(self, q, k, c, deadline_ms=None):
        self.q = q                      # HOST numpy row (PR 10): queries
        self.k = int(k)                 # stay host-side until the tick's
        self.c = float(c)               # single H2D at assembly
        self.future: Future = Future()
        self.t_submit = time.monotonic()
        # absolute monotonic deadline; None = no latency budget
        self.t_deadline = (None if deadline_ms is None
                           else self.t_submit + float(deadline_ms) / 1e3)

    @property
    def key(self):
        return (self.k, self.c)


class _InflightTick:
    """One dispatched-but-uncompleted tick: the unit the completion stage
    consumes. `res` holds the engine call's UNMATERIALIZED device arrays
    (JAX async dispatch) — nothing here has blocked on the device yet."""

    __slots__ = ("reqs", "res", "snap", "epoch", "k", "c_eff", "depth",
                 "rejected", "expired", "level", "t_dispatch", "compiles",
                 "inflight")

    def __init__(self, reqs, res, snap, epoch, k, c_eff, depth, rejected,
                 expired, level, t_dispatch, compiles):
        self.reqs = reqs
        self.res = res
        self.snap = snap
        self.epoch = epoch
        self.k = k
        self.c_eff = c_eff
        self.depth = depth
        self.rejected = rejected
        self.expired = expired
        self.level = level
        self.t_dispatch = t_dispatch
        self.compiles = compiles
        self.inflight = 1       # occupancy at dispatch; set at append


class MicroBatcher:
    """Coalesce async single-query submissions into `query_batch` ticks.

    Usage::

        with MicroBatcher(eng, max_batch=16, max_wait_ms=2.0) as mb:
            futs = [mb.submit(q, k=10, c=2.0) for q in queries]
            results = [f.result() for f in futs]     # QueryResult each
            print(mb.stats())

    Thread-safe; one background dispatcher thread. `close()` (or leaving
    the context) drains the queue before the thread exits, so every
    accepted Future resolves.
    """

    def __init__(self, engine, *, max_batch: int = 16,
                 max_wait_ms: float = 2.0, max_depth: Optional[int] = None,
                 auditor=None, degrade=None, pipeline_depth: int = 2):
        # Width 1 is rejected, not padded around: the module's partial-tick
        # bit-identity argument needs every dispatch ≥ 2 wide (matvec
        # lowering caveat, module doc), and a max_batch=1 scheduler could
        # never form a wider tick.
        if max_batch < 2:
            raise ValueError(f"max_batch must be >= 2 (width-1 dispatches "
                             f"lower as a matvec and break partial-tick "
                             f"bit-identity), got {max_batch}")
        if max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, got {max_wait_ms}")
        if max_depth is not None and max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        if pipeline_depth < 1:
            raise ValueError(
                f"pipeline_depth must be >= 1, got {pipeline_depth}")
        self.engine = engine
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self.max_depth = None if max_depth is None else int(max_depth)
        # Ticks allowed in flight (dispatched, not yet completed): 1 is
        # the synchronous schedule, 2 the double-buffered default — the
        # completion stage of tick t overlaps the device work of t+1.
        self.pipeline_depth = int(pipeline_depth)
        # Optional shadow auditor (repro.obs.audit.QualityAuditor): every
        # resolved request is OFFERED to it with the pinned snapshot; the
        # auditor samples and re-scores off-thread, never blocking ticks.
        self.auditor = auditor
        # Optional degrade-ladder controller (repro.serve.degrade): asked
        # for the current rung at every tick cut; None = always rung 0.
        self.degrade = degrade
        reg = obs.get_default()
        self._m_submitted = reg.counter(
            "serve_requests_total", "requests accepted by submit()")
        self._m_rejected = reg.counter(
            "serve_rejected_total", "submits rejected by back-pressure")
        # Per-reason reject counters (same metric name, a `reason` label
        # per REJECT_REASONS value; the unlabelled aggregate above stays
        # for pre-PR-9 dashboards).
        self._m_reject_reason = {
            reason: reg.counter(
                "serve_rejected_total", "rejects by reason",
                labels={"reason": reason})
            for reason in REJECT_REASONS}
        self._m_ticks = reg.counter(
            "serve_ticks_total", "dispatched micro-batch ticks")
        self._m_compiles = reg.counter(
            "serve_compiles_total", "XLA programs compiled during ticks")
        self._m_depth = reg.gauge(
            "serve_queue_depth", "queue length at the last tick cut")
        self._m_fill = reg.gauge(
            "serve_tick_fill_ratio", "fill ratio of the last tick")
        self._m_latency = reg.histogram(
            "serve_request_latency_ms", "submit → resolve latency")
        self._m_wait = reg.histogram(
            "serve_queue_wait_ms", "submit → dispatch queue wait")
        self._m_inflight = reg.gauge(
            "serve_inflight_ticks",
            "ticks dispatched but not yet completed")
        self._m_transfer = reg.histogram(
            "serve_transfer_ms", "per-tick D2H readback time")
        self._m_admission = reg.counter(
            "serve_admission_hits_total",
            "submits resolved from the LRU at admission")
        self._queue: Deque[_Request] = deque()
        self._cond = threading.Condition()
        self._stop = False
        self._closed = False        # close() already ran (idempotency)
        self._drain_deadline = None  # monotonic bound on close() draining
        self._flush = False
        self._busy = False          # a tick is being dispatched right now
        self._ticks: List[TickStats] = []
        self._rejected_total = 0
        self._rejected_since_tick = 0
        self._expired_total = 0
        self._expired_since_tick = 0
        self._depth_hwm = 0
        # The pipeline's bounded in-flight queue: dispatch appends,
        # completion peeks/pops FIFO (so futures resolve in dispatch
        # order and flush() sees a tick until it is fully resolved).
        self._inflight: Deque[_InflightTick] = deque()
        self._complete_stop = False
        self._admission_hits = 0
        self._admission_lat: List[float] = []
        # Admission-path LRU (PR 10): when the engine's backend composes
        # a CachingBackend AND the engine is snapshot-versioned, submit
        # probes the cache first — a hit resolves immediately and never
        # occupies a queue or tick slot.
        self._admission_cache = None
        if getattr(engine, "current_snapshot", None) is not None:
            bk = getattr(engine, "_backend", None)
            if bk is not None:
                try:
                    from repro.serve.degrade import find_cache
                    self._admission_cache = find_cache(bk)
                except Exception:
                    self._admission_cache = None
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="microbatcher")
        self._complete_thread = threading.Thread(
            target=self._completion_loop, daemon=True,
            name="microbatcher-complete")
        self._thread.start()
        self._complete_thread.start()

    # ------------------------------------------------------------- client
    def submit(self, q: jax.Array, k: int, c: float,
               deadline_ms: Optional[float] = None) -> Future:
        """Enqueue one (d,) query; resolves to its per-query QueryResult
        with HOST (numpy) leaves, leading batch axis already squeezed —
        serving results are client-bound, so the tick is transferred once
        and split into zero-copy row views.

        With `max_depth` set, a submit that finds the queue at the bound
        raises `QueueFull` immediately (fail-fast back-pressure) instead
        of accepting work the scheduler cannot keep up with.

        `deadline_ms` attaches a latency budget relative to NOW: a
        non-positive budget is rejected at admission with
        `DeadlineExceeded`, and a queued request whose budget expires
        before its tick is cut is failed by the per-tick sweep (its
        Future raises `DeadlineExceeded`). After `close()`, submits
        raise `SchedulerClosed` (reject reason `shutdown`).

        PR 10: the query is kept as HOST numpy until tick assembly (no
        per-submit H2D), and when the engine's backend composes a
        CachingBackend an exact LRU hit for the live snapshot resolves
        the Future right here — it never occupies a queue or tick slot
        (`ServeStats.admission_hits`)."""
        q = np.asarray(jax.device_get(q))
        if q.ndim != 1:
            raise ValueError(f"submit expects a (d,) query; got {q.shape}")
        if q.dtype == np.float64:
            # mirror jnp.asarray's default-dtype conversion (x64 off) so
            # host-resident submission changes no tick bytes
            q = q.astype(np.float32)
        if deadline_ms is not None and deadline_ms <= 0:
            # already expired at admission: shed before it can take a
            # queue slot, let alone a tick slot
            with self._cond:
                self._expired_total += 1
                self._expired_since_tick += 1
            self._m_reject_reason["deadline"].inc()
            raise DeadlineExceeded(
                f"deadline_ms={deadline_ms} already expired at submit")
        if self._admission_cache is not None and not self._stop:
            fut = self._admission_probe(q, int(k), float(c))
            if fut is not None:
                return fut
        req = _Request(q, k, c, deadline_ms=deadline_ms)
        with self._cond:
            if self._stop:
                # Not counted into _rejected_total: the dispatcher has
                # (or will have) exited, so no terminal TickStats could
                # attribute it — the labelled counter is the record.
                self._m_reject_reason["shutdown"].inc()
                raise SchedulerClosed("MicroBatcher is closed")
            if (self.max_depth is not None
                    and len(self._queue) >= self.max_depth):
                self._rejected_total += 1
                self._rejected_since_tick += 1
                self._m_rejected.inc()
                self._m_reject_reason["queue_full"].inc()
                raise QueueFull(
                    f"queue at max_depth={self.max_depth}; request rejected "
                    "(fail-fast back-pressure — retry with backoff)")
            self._queue.append(req)
            self._depth_hwm = max(self._depth_hwm, len(self._queue))
            self._cond.notify_all()
        self._m_submitted.inc()
        return req.future

    def _admission_probe(self, q: np.ndarray, k: int,
                         c: float) -> Optional[Future]:
        """LRU probe on the admission path: a resolved Future when the
        exact (query, k, c) is cached for the live snapshot, else None
        (the request then takes the normal queue path). Misses are not
        counted against the cache's hit-rate (`record_miss=False`) —
        they go on to dispatch through the backend, which counts them.
        Probe failures (e.g. an engine mid-teardown) degrade to the
        queue path rather than failing the submit."""
        t0 = time.monotonic()
        try:
            snap = self.engine.current_snapshot()
            res = self._admission_cache.lookup_only(
                snap.rank_table, snap.query_users(), q, k=k, c=c,
                delta=snap.corr, record_miss=False)
        except Exception:
            return None
        if res is None:
            return None
        host = jax.device_get(res)
        lat_ms = (time.monotonic() - t0) * 1e3
        with self._cond:
            self._admission_hits += 1
            self._admission_lat.append(lat_ms)
        self._m_submitted.inc()
        self._m_admission.inc()
        self._m_latency.observe(lat_ms)
        fut: Future = Future()
        fut.set_result(host)
        if self.auditor is not None:
            self.auditor.observe(np.asarray(q), host, k=k, c=c,
                                 snapshot=snap)
        return fut

    def flush(self) -> None:
        """Dispatch everything queued without waiting out `max_wait_ms`,
        and block until all accepted requests have resolved."""
        with self._cond:
            self._flush = True
            self._cond.notify_all()
            while self._queue or self._busy or self._inflight:
                self._cond.wait(timeout=0.05)
            self._flush = False

    def close(self, drain_s: Optional[float] = None) -> None:
        """Drain the queue, then stop the dispatcher thread. Idempotent —
        a second close() returns immediately.

        `drain_s` bounds the drain: queued requests still undispatched
        when the budget runs out are shed (`SchedulerClosed`, reject
        reason `shutdown`) instead of holding up shutdown behind a slow
        engine. The default None drains fully, as before."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._stop = True
            if drain_s is not None:
                self._drain_deadline = time.monotonic() + float(drain_s)
            self._cond.notify_all()
        self._thread.join()
        # The dispatcher's exit signalled the completion stage to drain
        # the remaining in-flight ticks and flush the terminal record;
        # joining it makes close() a full barrier (every accepted Future
        # resolved, every reject attributed) exactly as before.
        self._complete_thread.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def stats(self) -> ServeStats:
        """Aggregate tick statistics (p50/p99 over request latencies,
        admission-path hits pooled in)."""
        with self._cond:            # one atomic snapshot of ticks+counters
            ticks = list(self._ticks)
            rejected, hwm = self._rejected_total, self._depth_hwm
            expired = self._expired_total
            adm = self._admission_hits
            adm_lat = list(self._admission_lat)
        if not ticks and not adm_lat:
            return ServeStats(0, 0, 0.0, 0.0, 0.0, 0.0, rejected=rejected,
                              depth_hwm=hwm, expired=expired,
                              admission_hits=adm)
        # The terminal rejection record (batch == 0, no latencies) is an
        # accounting tick: it carries rejects into the aggregate but must
        # not skew the dispatch-shape means or crash the percentiles.
        dispatched = [t for t in ticks if t.batch > 0]
        lats = np.concatenate(
            [np.asarray(t.latencies_ms, dtype=float) for t in ticks]
            + [np.asarray(adm_lat, dtype=float)])
        return ServeStats(
            ticks=len(ticks),
            requests=int(lats.size),
            mean_fill=(float(np.mean([t.fill_ratio for t in dispatched]))
                       if dispatched else 0.0),
            mean_queue_depth=(
                float(np.mean([t.queue_depth for t in dispatched]))
                if dispatched else 0.0),
            p50_ms=float(np.percentile(lats, 50)) if lats.size else 0.0,
            p99_ms=float(np.percentile(lats, 99)) if lats.size else 0.0,
            rejected=rejected,
            depth_hwm=hwm,
            expired=expired,
            admission_hits=adm,
            overlap_efficiency=(
                float(np.mean([t.inflight > 1 for t in dispatched]))
                if dispatched else 0.0),
        )

    @property
    def tick_log(self) -> List[TickStats]:
        with self._cond:
            return list(self._ticks)

    # --------------------------------------------------------- dispatcher
    def _full_key(self):
        """The (k, c) of the first group to reach `max_batch` queued
        requests, or None. Requests with different static args cannot
        share a tick (k/c are compiled into the batch program), but a
        FULL group behind a lone straggler head is dispatchable NOW —
        waiting out the head's deadline would be head-of-line blocking."""
        counts: dict = {}
        for r in self._queue:
            counts[r.key] = counts.get(r.key, 0) + 1
            if counts[r.key] >= self.max_batch:
                return r.key
        return None

    def _sweep_expired(self, now: float) -> List[_Request]:
        """Remove deadline-expired requests from the queue (lock held).
        Returns the shed requests — their futures are failed OUTSIDE the
        lock (`_fail_expired`), so a future callback can never deadlock
        against the scheduler."""
        if not any(r.t_deadline is not None and now >= r.t_deadline
                   for r in self._queue):
            return []
        keep: Deque[_Request] = deque()
        dead: List[_Request] = []
        for r in self._queue:
            if r.t_deadline is not None and now >= r.t_deadline:
                dead.append(r)
            else:
                keep.append(r)
        self._queue = keep
        self._expired_total += len(dead)
        self._expired_since_tick += len(dead)
        return dead

    def _fail_expired(self, reqs: List[_Request]) -> None:
        for r in reqs:
            self._m_reject_reason["deadline"].inc()
            if not r.future.cancelled():
                r.future.set_exception(DeadlineExceeded(
                    "deadline expired before dispatch (per-tick sweep)"))

    def _fail_drained(self, reqs: List[_Request]) -> None:
        for r in reqs:
            self._m_reject_reason["shutdown"].inc()
            if not r.future.cancelled():
                r.future.set_exception(SchedulerClosed(
                    "scheduler closed before dispatch (bounded drain)"))

    def _loop(self):
        while True:
            expired: List[_Request] = []
            drained: List[_Request] = []
            reqs = None
            terminal = False
            with self._cond:
                while not self._queue and not self._stop:
                    self._cond.wait()
                now = time.monotonic()
                # Deadline sweep FIRST: an expired request must not be
                # chosen as the head nor occupy a tick slot.
                expired += self._sweep_expired(now)
                if (self._stop and self._queue
                        and self._drain_deadline is not None
                        and now >= self._drain_deadline):
                    # bounded drain exhausted: shed the remainder so
                    # close(drain_s=...) returns promptly; the sheds flow
                    # into the terminal accounting record below
                    drained = list(self._queue)
                    self._queue.clear()
                    self._rejected_total += len(drained)
                    self._rejected_since_tick += len(drained)
                if not self._queue:
                    if self._stop:      # stop requested, queue drained
                        # Hand off to the completion stage: in-flight
                        # ticks may still fail and re-credit their
                        # reject/expiry attribution, so the terminal
                        # accounting record is flushed THERE, after the
                        # pipeline drains (`_completion_loop`).
                        self._complete_stop = True
                        self._cond.notify_all()
                        terminal = True
                    # else: the sweep emptied the queue mid-serve — fail
                    # the shed futures below and go back to waiting
                else:
                    # Pipeline back-pressure: at most `pipeline_depth`
                    # ticks in flight; completion pops wake this wait. A
                    # bounded drain that expires while waiting falls
                    # through (reqs stays None) to the top-of-loop shed
                    # instead of cutting past the depth bound.
                    while len(self._inflight) >= self.pipeline_depth:
                        if (self._stop and self._drain_deadline is not None
                                and time.monotonic()
                                >= self._drain_deadline):
                            break
                        self._cond.wait(timeout=0.05)
                    if len(self._inflight) < self.pipeline_depth:
                        # a budget may have lapsed during the slot wait
                        expired += self._sweep_expired(time.monotonic())
                    if (len(self._inflight) < self.pipeline_depth
                            and self._queue):
                        head = self._queue[0]
                        deadline = head.t_submit + self.max_wait_ms / 1e3
                        while (self._full_key() is None
                               and not (self._stop or self._flush)):
                            remaining = deadline - time.monotonic()
                            if remaining <= 0:
                                break
                            self._cond.wait(timeout=remaining)
                        # late sweep: a request whose budget ran out
                        # DURING the coalescing wait must not take a
                        # tick slot
                        expired += self._sweep_expired(time.monotonic())
                        if self._queue:
                            # a full group anywhere in the queue outranks
                            # the partial head tick; the head still
                            # dispatches by its deadline
                            key = self._full_key() or self._queue[0].key
                            reqs, rest = [], deque()
                            while self._queue:
                                r = self._queue.popleft()
                                if (r.key == key
                                        and len(reqs) < self.max_batch):
                                    reqs.append(r)
                                else:
                                    rest.append(r)
                            depth = len(reqs) + len(rest)
                            self._queue = rest
                            rejected = self._rejected_since_tick
                            self._rejected_since_tick = 0
                            n_expired = self._expired_since_tick
                            self._expired_since_tick = 0
                            # degrade rung for this tick, from the queue
                            # depth observed at the cut (hysteresis
                            # inside the controller — repro.serve.degrade)
                            level = (self.degrade.on_tick_cut(depth)
                                     if self.degrade is not None else 0)
                            self._busy = True
            if expired:
                self._fail_expired(expired)
            if drained:
                self._fail_drained(drained)
            if terminal:
                return
            if reqs is None:
                continue
            try:
                self._dispatch(reqs, depth, rejected, n_expired, level)
            finally:
                with self._cond:
                    self._busy = False
                    self._cond.notify_all()

    def _assemble_block(self, reqs: List[_Request]) -> np.ndarray:
        """Host-side tick assembly (PR 10): stack and edge-pad the HOST
        query rows in numpy, so the whole tick pays exactly ONE H2D
        (inside the backend's `dispatch_device`) instead of per-submit
        transfers plus a device-side pad. Pad semantics match
        `pad_block` exactly — same bytes, so bit-identity to the
        synchronous path is preserved."""
        qs = np.stack([r.q for r in reqs])
        b = qs.shape[0]
        if b < self.max_batch:
            qs = np.concatenate(
                [qs, np.broadcast_to(qs[-1:],
                                     (self.max_batch - b, qs.shape[1]))])
        return qs

    def _dispatch(self, reqs: List[_Request], depth: int, rejected: int = 0,
                  expired: int = 0, level: int = 0):
        """DISPATCH stage: assemble, stage, and launch the tick's device
        work, then hand an `_InflightTick` to the completion stage — no
        host sync on this thread (the JAX dispatch returns unmaterialized
        device arrays; `_complete` performs the single blocking D2H)."""
        t_dispatch = time.monotonic()
        k, c = reqs[0].key
        # rung 2+ of the degrade ladder dispatches at a WIDENED contract:
        # the result is a valid c_eff-approximation, reported as such
        # (TickStats.degrade_level) and audited at c_eff (module doc)
        c_eff = (self.degrade.widened_c(c)
                 if self.degrade is not None else c)
        if (level >= 3 and self.degrade is not None
                and self.degrade.cache is not None
                and getattr(self.engine, "current_snapshot", None)
                is not None):
            self._dispatch_cache_only(reqs, depth, rejected, expired,
                                      level, t_dispatch)
            return
        epoch = None
        snap = None
        programs_before = _program_count()
        sp = trace.span("serve.tick", batch=len(reqs), depth=depth, k=k)
        try:
            with sp:
                if faults.ACTIVE is not None:
                    faults.fire("serve.slow_tick")
                    faults.fire("serve.dispatch")
                if trace.is_enabled():
                    # retroactive cross-thread spans: each request's
                    # admission → dispatch queue wait, timed from its
                    # client-thread submit; inside the tick span so the
                    # records attribute to the tick that served them
                    for r in reqs:
                        trace.event("serve.queue_wait", r.t_submit,
                                    t_dispatch - r.t_submit, k=k)
                qs = self._assemble_block(reqs)
                # Pin ONE index snapshot for the whole tick (module doc):
                # a hot-swap concurrent with this dispatch lands between
                # ticks, never inside one.
                snap_fn = getattr(self.engine, "current_snapshot", None)
                dispatch_fn = getattr(self.engine, "dispatch_batch_at",
                                      None)
                if snap_fn is not None:
                    snap = snap_fn()
                    epoch = getattr(snap, "epoch", None)
                    sp.set(epoch=epoch)
                    if dispatch_fn is not None:
                        # the serving entry: one H2D, device handles out,
                        # donation-safe on accelerators
                        res = dispatch_fn(snap, qs, k=k, c=c_eff)
                    else:
                        res = self.engine.query_batch_at(
                            snap, jnp.asarray(qs), k=k, c=c_eff)
                else:
                    res = self.engine.query_batch(jnp.asarray(qs), k=k,
                                                  c=c_eff)
        except Exception as e:                    # propagate to every caller
            for r in reqs:
                if not r.future.cancelled():
                    r.future.set_exception(e)
            # This tick records no TickStats — re-credit the rejects and
            # expiries it was carrying so the NEXT cut (or the terminal
            # flush at close) attributes them instead of dropping them.
            with self._cond:
                self._rejected_since_tick += rejected
                self._expired_since_tick += expired
            return
        # Compile attribution is sampled HERE, not in the completion
        # stage: tracing/compilation happens synchronously on this
        # thread, so the delta cleanly brackets this tick's dispatch even
        # with other ticks in flight.
        tick = _InflightTick(
            reqs, res, snap, epoch, k, c_eff, depth, rejected, expired,
            level, t_dispatch,
            compiles=max(0, _program_count() - programs_before))
        with self._cond:
            self._inflight.append(tick)
            tick.inflight = len(self._inflight)
            self._m_inflight.set(len(self._inflight))
            self._cond.notify_all()

    # --------------------------------------------------------- completion
    def _completion_loop(self):
        """COMPLETION stage: consume in-flight ticks FIFO, each with one
        blocking D2H, and resolve futures — entirely off the dispatch
        path. Exits after the dispatcher signals `_complete_stop` and the
        pipeline drains, flushing the terminal accounting record last (a
        completion-stage failure re-credits rejects, so the terminal
        flush must come after the final tick settles)."""
        while True:
            with self._cond:
                while not self._inflight and not self._complete_stop:
                    self._cond.wait()
                if not self._inflight:          # stopping and drained
                    tail = self._rejected_since_tick
                    self._rejected_since_tick = 0
                    tail_exp = self._expired_since_tick
                    self._expired_since_tick = 0
                    if tail or tail_exp:
                        self._ticks.append(TickStats(
                            batch=0, queue_depth=0, fill_ratio=0.0,
                            wait_ms=0.0, latencies_ms=(),
                            rejected=tail, expired=tail_exp))
                    self._cond.notify_all()
                    return
                # PEEK, don't pop: flush()/close() must keep seeing the
                # tick until its futures are resolved.
                tick = self._inflight[0]
            self._complete(tick)
            with self._cond:
                self._inflight.popleft()
                self._m_inflight.set(len(self._inflight))
                self._cond.notify_all()

    def _complete(self, t: _InflightTick):
        reqs = t.reqs
        t_transfer = time.monotonic()
        try:
            with trace.span("serve.transfer", batch=len(reqs),
                            epoch=t.epoch, inflight=t.inflight):
                if faults.ACTIVE is not None:
                    faults.fire("serve.transfer")
                # THE one blocking D2H per tick: futures resolve to HOST
                # (numpy) QueryResults — per-request row views are
                # zero-copy, where B×fields device slices would dominate
                # the tick cost. A deferred dispatch error (async
                # runtime) also surfaces here and is failed typed below.
                host = jax.device_get(t.res)
        except Exception as e:
            # Fail exactly THIS tick's futures; later in-flight ticks
            # keep completing. Reject/expiry attribution re-credits to
            # the next cut or the terminal flush (PR 9 invariant: every
            # reject lands in exactly one TickStats).
            for r in reqs:
                if not r.future.cancelled():
                    r.future.set_exception(e)
            with self._cond:
                self._rejected_since_tick += t.rejected
                self._expired_since_tick += t.expired
            return
        now = time.monotonic()
        transfer_ms = (now - t_transfer) * 1e3
        tick = TickStats(
            batch=len(reqs), queue_depth=t.depth,
            fill_ratio=len(reqs) / self.max_batch,
            wait_ms=(t.t_dispatch - reqs[0].t_submit) * 1e3,
            latencies_ms=tuple((now - r.t_submit) * 1e3 for r in reqs),
            rejected=t.rejected, epoch=t.epoch,
            compiles=t.compiles,
            expired=t.expired, degrade_level=t.level,
            transfer_ms=transfer_ms, inflight=t.inflight)
        # Record the tick BEFORE resolving futures: a client that wakes
        # from f.result() must already see it in stats()/tick_log.
        with self._cond:
            self._ticks.append(tick)
        self._m_ticks.inc()
        if tick.compiles:
            self._m_compiles.inc(tick.compiles)
        self._m_depth.set(t.depth)
        self._m_fill.set(tick.fill_ratio)
        self._m_transfer.observe(transfer_ms)
        for r in reqs:
            self._m_wait.observe((t.t_dispatch - r.t_submit) * 1e3)
            self._m_latency.observe((now - r.t_submit) * 1e3)
        for i, r in enumerate(reqs):              # pad rows masked out here
            per_q = jax.tree_util.tree_map(lambda x, i=i: x[i], host)
            if not r.future.cancelled():
                r.future.set_result(per_q)
            if self.auditor is not None:
                # audited at the contract actually served (c_eff on
                # degraded ticks) — the accuracy gauge judges the
                # relaxed, REPORTED contract, not the requested one
                self.auditor.observe(np.asarray(r.q), per_q, k=t.k,
                                     c=t.c_eff, snapshot=t.snap)

    def _dispatch_cache_only(self, reqs: List[_Request], depth: int,
                             rejected: int, expired: int, level: int,
                             t_dispatch: float):
        """Degrade rung 3: answer LRU hits against the pinned snapshot,
        shed misses with `QueueFull` (reject reason `degraded`).

        A hit is an exact per-query result computed earlier in the SAME
        index generation — the cache invalidates on any snapshot change
        (`CachingBackend._check_epoch`), so its certified (r↓, r↑) bounds
        are as valid as at first compute. Misses shed instead of
        dispatching: rung 3 exists to take the rank table out of the
        serving path entirely."""
        cache = self.degrade.cache
        k, c = reqs[0].key
        c_eff = self.degrade.widened_c(c)
        snap = self.engine.current_snapshot()
        epoch = getattr(snap, "epoch", None)
        rt, users, delta = snap.rank_table, snap.query_users(), snap.corr
        hits: List[Tuple[_Request, object, float]] = []
        misses: List[_Request] = []
        with trace.span("serve.cache_only", batch=len(reqs), depth=depth,
                        k=k, epoch=epoch, level=level):
            for r in reqs:
                row = np.asarray(r.q)       # host already (PR 10 submit)
                # entries may have been cached at the base contract or at
                # the rung-2 widened one — a hit at either serves
                res, c_hit = None, c
                for c_try in ((c, c_eff) if c_eff != c else (c,)):
                    res = cache.lookup_only(rt, users, row, k=k, c=c_try,
                                            delta=delta)
                    if res is not None:
                        c_hit = c_try
                        break
                if res is None:
                    misses.append(r)
                else:
                    hits.append((r, res, c_hit))
            if hits:
                # ONE D2H for the whole rung-3 tick (the per-request
                # device_get here was measurable: B blocking transfers
                # per tick, exactly the pattern PR 10 removes). Cached
                # entries are device-resident per-query QueryResults;
                # device_get over the list batches them.
                hosts = jax.device_get([res for _, res, _ in hits])
                hits = [(r, h, c_hit) for (r, _, c_hit), h
                        in zip(hits, hosts)]
        with self._cond:
            self._rejected_total += len(misses)
        now = time.monotonic()
        tick = TickStats(
            batch=len(hits), queue_depth=depth,
            fill_ratio=len(hits) / self.max_batch,
            wait_ms=(t_dispatch - reqs[0].t_submit) * 1e3,
            latencies_ms=tuple((now - r.t_submit) * 1e3
                               for r, _, _ in hits),
            rejected=rejected + len(misses), epoch=epoch,
            expired=expired, degrade_level=level)
        with self._cond:
            self._ticks.append(tick)
        self._m_ticks.inc()
        self._m_depth.set(depth)
        self._m_fill.set(tick.fill_ratio)
        if misses:
            self._m_rejected.inc(len(misses))
            self._m_reject_reason["degraded"].inc(len(misses))
        for r in misses:
            if not r.future.cancelled():
                r.future.set_exception(QueueFull(
                    "shed at degrade level 3 (cache-only serving): "
                    "no cached result for this query"))
        for r, host, c_hit in hits:
            self._m_wait.observe((t_dispatch - r.t_submit) * 1e3)
            self._m_latency.observe((now - r.t_submit) * 1e3)
            if not r.future.cancelled():
                r.future.set_result(host)
            if self.auditor is not None:
                self.auditor.observe(np.asarray(r.q), host, k=k, c=c_hit,
                                     snapshot=snap)
