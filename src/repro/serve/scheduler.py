"""Async micro-batching request scheduler — single queries in, B-sized
`engine.query_batch` ticks out.

The paper's item-centric workload is served ONLINE: queries arrive one at
a time, but PR 1 made the B-query block the cheap unit of work (the
(n, τ) rank table and (n, d) user matrix are streamed once per block, not
once per query). `MicroBatcher` closes that gap: `submit(q, k, c)`
returns a Future immediately; a dispatcher thread coalesces queued
requests into ticks of up to `max_batch` queries and executes each tick
as ONE `engine.query_batch` call.

Latency-vs-throughput knob
--------------------------
A tick dispatches as soon as any (k, c) group reaches `max_batch` queued
requests, or `max_wait_ms` after the head request arrived — whichever
comes first. Small `max_wait_ms` bounds queueing latency at low offered
load (ticks go out nearly empty); large `max_wait_ms` trades latency for
fill ratio and table-bandwidth amortization (see
`benchmarks/perf_engine.py --serve` for the measured curve). Requests
with different (k, c) never share a tick — those are static arguments of
the compiled batch program — and a FULL group behind a straggler head
dispatches immediately rather than waiting out the head's deadline.

Partial-batch padding
---------------------
Partial ticks are EDGE-PADDED to the compiled `max_batch` shape
(`pad_block`), so every tick reuses one compiled XLA program instead of
retracing per fill level; pad rows are sliced off before the Futures
resolve. Padding is numerically invisible: a batched matmul's output
column (i, j) depends only on the user row i, query column j, and the
accumulation order — not on the other columns' VALUES — so the real
rows of a padded tick are bit-identical to dispatching the unpadded
block directly (asserted per backend in tests/test_serve.py). The one
platform caveat: a width-1 block lowers as a matvec with a different
accumulation order, so `pad_block` never emits width-1 dispatches and
bit-identity holds for every partial fill ≥ 2; a singleton tick is
padded like any other and agrees with direct execution on every
table-derived field (indices, r↓/r↑, R↓_k/R↑_k), with `est` equal to
float accuracy.

Back-pressure
-------------
`max_depth` bounds the queue: a `submit` that would push the queue past
it FAILS FAST with `QueueFull` instead of growing an unbounded backlog
(under sustained overload an unbounded queue turns finite latency into
infinite latency for everyone). Rejections are counted per tick
(`TickStats.rejected` — rejections observed since the previous tick) and
in aggregate (`ServeStats.rejected`, plus the queue-depth high-watermark)
so dashboards can see the overload knee; `benchmarks/perf_engine.py
--serve` sweeps offered load past capacity and reports the column.

Snapshot-pinned ticks
---------------------
When the engine is snapshot-versioned (`repro.index`: mutable engines
publish epoch-versioned `IndexSnapshot`s), every tick PINS one snapshot
(`engine.current_snapshot()`) and dispatches the whole batch against it
via `engine.query_batch_at`, recording the epoch in `TickStats.epoch`.
A concurrent mutation or rebuild hot-swap therefore lands BETWEEN ticks,
never inside one: all futures of a tick resolve against exactly one
index generation (asserted in tests/test_index.py). Engines without
snapshots dispatch through plain `engine.query_batch`.

Per-tick stats (`TickStats`) record queue depth at dispatch, fill ratio,
and per-request latency; `MicroBatcher.stats()` aggregates them into
p50/p99 latency for the serving dashboards.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Deque, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import registry as obs
from repro.obs import trace


def pad_block(qs: jax.Array, max_batch: int) -> jax.Array:
    """Edge-pad a (B, d) query block to the compiled (max_batch, d) shape.

    Pad rows repeat the last real query: their columns are well-defined on
    every backend and are masked out of results by slicing. B = 0 or
    B > max_batch are caller errors, and so is max_batch < 2: the padded
    width is the dispatch width, and a width-1 dispatch lowers as a
    matvec with a different accumulation order — the exact case the
    module's "dispatches never shrink below width 2" bit-identity
    invariant (module doc) exists to rule out.
    """
    if max_batch < 2:
        raise ValueError(f"max_batch must be >= 2 (width-1 dispatches "
                         f"lower as a matvec and break partial-tick "
                         f"bit-identity); got {max_batch}")
    b = qs.shape[0]
    if not 1 <= b <= max_batch:
        raise ValueError(f"block of {b} queries does not fit max_batch="
                         f"{max_batch}")
    if b == max_batch:
        return qs
    return jnp.concatenate(
        [qs, jnp.broadcast_to(qs[-1:], (max_batch - b, qs.shape[1]))])


def _program_count() -> int:
    """Compiled-program count across the query stack (0 if unavailable).

    Deferred import: the counter lives with the elastic backend
    (`repro.core.elastic.compiled_program_count`), whose module this one
    must not import at load time (serve ↔ core layering)."""
    try:
        from repro.core.elastic import compiled_program_count
        return compiled_program_count()
    except Exception:
        return 0


class QueueFull(RuntimeError):
    """`submit` rejected: the queue is at `max_depth` (back-pressure)."""


@dataclasses.dataclass(frozen=True)
class TickStats:
    """One dispatched tick, as observed by the scheduler."""

    batch: int                 # real (unpadded) queries in the tick
    queue_depth: int           # queue length when the tick was formed
    fill_ratio: float          # batch / max_batch
    wait_ms: float             # head request's submit → dispatch wait
    latencies_ms: Tuple[float, ...]   # per-request submit → resolve
    rejected: int = 0          # submits rejected since the previous tick
    epoch: Optional[int] = None  # pinned index epoch (snapshot engines)
    # Query-stack XLA programs compiled DURING this tick's dispatch
    # (repro.core.elastic.compiled_program_count delta). Nonzero only on
    # warm-up ticks; a nonzero value on a steady-state tick is the
    # recompile-storm signature the elastic backend exists to kill, and
    # exactly what its p99 spike looks like to a dashboard.
    compiles: int = 0
    # A terminal record (batch == 0) is flushed at close() when rejects
    # arrived after the last dispatched tick — every rejection is
    # attributed to exactly one TickStats.


@dataclasses.dataclass(frozen=True)
class ServeStats:
    """Aggregate over a MicroBatcher's lifetime (see `stats()`)."""

    ticks: int
    requests: int
    mean_fill: float
    mean_queue_depth: float
    p50_ms: float
    p99_ms: float
    rejected: int = 0          # submits rejected by the max_depth bound
    depth_hwm: int = 0         # queue-depth high-watermark

    def __str__(self):
        return (f"{self.requests} reqs / {self.ticks} ticks  "
                f"fill {self.mean_fill:.2f}  depth {self.mean_queue_depth:.1f}"
                f" (hwm {self.depth_hwm})  rej {self.rejected}"
                f"  p50 {self.p50_ms:.2f} ms  p99 {self.p99_ms:.2f} ms")


class _Request:
    __slots__ = ("q", "k", "c", "future", "t_submit")

    def __init__(self, q, k, c):
        self.q = q
        self.k = int(k)
        self.c = float(c)
        self.future: Future = Future()
        self.t_submit = time.monotonic()

    @property
    def key(self):
        return (self.k, self.c)


class MicroBatcher:
    """Coalesce async single-query submissions into `query_batch` ticks.

    Usage::

        with MicroBatcher(eng, max_batch=16, max_wait_ms=2.0) as mb:
            futs = [mb.submit(q, k=10, c=2.0) for q in queries]
            results = [f.result() for f in futs]     # QueryResult each
            print(mb.stats())

    Thread-safe; one background dispatcher thread. `close()` (or leaving
    the context) drains the queue before the thread exits, so every
    accepted Future resolves.
    """

    def __init__(self, engine, *, max_batch: int = 16,
                 max_wait_ms: float = 2.0, max_depth: Optional[int] = None,
                 auditor=None):
        # Width 1 is rejected, not padded around: the module's partial-tick
        # bit-identity argument needs every dispatch ≥ 2 wide (matvec
        # lowering caveat, module doc), and a max_batch=1 scheduler could
        # never form a wider tick.
        if max_batch < 2:
            raise ValueError(f"max_batch must be >= 2 (width-1 dispatches "
                             f"lower as a matvec and break partial-tick "
                             f"bit-identity), got {max_batch}")
        if max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, got {max_wait_ms}")
        if max_depth is not None and max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.engine = engine
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self.max_depth = None if max_depth is None else int(max_depth)
        # Optional shadow auditor (repro.obs.audit.QualityAuditor): every
        # resolved request is OFFERED to it with the pinned snapshot; the
        # auditor samples and re-scores off-thread, never blocking ticks.
        self.auditor = auditor
        reg = obs.get_default()
        self._m_submitted = reg.counter(
            "serve_requests_total", "requests accepted by submit()")
        self._m_rejected = reg.counter(
            "serve_rejected_total", "submits rejected by back-pressure")
        self._m_ticks = reg.counter(
            "serve_ticks_total", "dispatched micro-batch ticks")
        self._m_compiles = reg.counter(
            "serve_compiles_total", "XLA programs compiled during ticks")
        self._m_depth = reg.gauge(
            "serve_queue_depth", "queue length at the last tick cut")
        self._m_fill = reg.gauge(
            "serve_tick_fill_ratio", "fill ratio of the last tick")
        self._m_latency = reg.histogram(
            "serve_request_latency_ms", "submit → resolve latency")
        self._m_wait = reg.histogram(
            "serve_queue_wait_ms", "submit → dispatch queue wait")
        self._queue: Deque[_Request] = deque()
        self._cond = threading.Condition()
        self._stop = False
        self._flush = False
        self._busy = False          # a tick is being dispatched right now
        self._ticks: List[TickStats] = []
        self._rejected_total = 0
        self._rejected_since_tick = 0
        self._depth_hwm = 0
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="microbatcher")
        self._thread.start()

    # ------------------------------------------------------------- client
    def submit(self, q: jax.Array, k: int, c: float) -> Future:
        """Enqueue one (d,) query; resolves to its per-query QueryResult
        with HOST (numpy) leaves, leading batch axis already squeezed —
        serving results are client-bound, so the tick is transferred once
        and split into zero-copy row views.

        With `max_depth` set, a submit that finds the queue at the bound
        raises `QueueFull` immediately (fail-fast back-pressure) instead
        of accepting work the scheduler cannot keep up with."""
        q = jnp.asarray(q)
        if q.ndim != 1:
            raise ValueError(f"submit expects a (d,) query; got {q.shape}")
        req = _Request(q, k, c)
        with self._cond:
            if self._stop:
                raise RuntimeError("MicroBatcher is closed")
            if (self.max_depth is not None
                    and len(self._queue) >= self.max_depth):
                self._rejected_total += 1
                self._rejected_since_tick += 1
                self._m_rejected.inc()
                raise QueueFull(
                    f"queue at max_depth={self.max_depth}; request rejected "
                    "(fail-fast back-pressure — retry with backoff)")
            self._queue.append(req)
            self._depth_hwm = max(self._depth_hwm, len(self._queue))
            self._cond.notify_all()
        self._m_submitted.inc()
        return req.future

    def flush(self) -> None:
        """Dispatch everything queued without waiting out `max_wait_ms`,
        and block until all accepted requests have resolved."""
        with self._cond:
            self._flush = True
            self._cond.notify_all()
            while self._queue or self._busy:
                self._cond.wait(timeout=0.05)
            self._flush = False

    def close(self) -> None:
        """Drain the queue, then stop the dispatcher thread."""
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        self._thread.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def stats(self) -> ServeStats:
        """Aggregate tick statistics (p50/p99 over request latencies)."""
        with self._cond:            # one atomic snapshot of ticks+counters
            ticks = list(self._ticks)
            rejected, hwm = self._rejected_total, self._depth_hwm
        if not ticks:
            return ServeStats(0, 0, 0.0, 0.0, 0.0, 0.0, rejected=rejected,
                              depth_hwm=hwm)
        # The terminal rejection record (batch == 0, no latencies) is an
        # accounting tick: it carries rejects into the aggregate but must
        # not skew the dispatch-shape means or crash the percentiles.
        dispatched = [t for t in ticks if t.batch > 0]
        lats = np.concatenate(
            [np.asarray(t.latencies_ms, dtype=float) for t in ticks])
        return ServeStats(
            ticks=len(ticks),
            requests=int(lats.size),
            mean_fill=(float(np.mean([t.fill_ratio for t in dispatched]))
                       if dispatched else 0.0),
            mean_queue_depth=(
                float(np.mean([t.queue_depth for t in dispatched]))
                if dispatched else 0.0),
            p50_ms=float(np.percentile(lats, 50)) if lats.size else 0.0,
            p99_ms=float(np.percentile(lats, 99)) if lats.size else 0.0,
            rejected=rejected,
            depth_hwm=hwm,
        )

    @property
    def tick_log(self) -> List[TickStats]:
        with self._cond:
            return list(self._ticks)

    # --------------------------------------------------------- dispatcher
    def _full_key(self):
        """The (k, c) of the first group to reach `max_batch` queued
        requests, or None. Requests with different static args cannot
        share a tick (k/c are compiled into the batch program), but a
        FULL group behind a lone straggler head is dispatchable NOW —
        waiting out the head's deadline would be head-of-line blocking."""
        counts: dict = {}
        for r in self._queue:
            counts[r.key] = counts.get(r.key, 0) + 1
            if counts[r.key] >= self.max_batch:
                return r.key
        return None

    def _loop(self):
        while True:
            with self._cond:
                while not self._queue and not self._stop:
                    self._cond.wait()
                if not self._queue:         # stop requested, queue drained
                    # Rejects that arrived AFTER the last tick was cut
                    # would otherwise vanish (they are only read at the
                    # next cut, and there is no next cut): flush them
                    # into a terminal accounting record so ServeStats
                    # and tick_log stay complete under close().
                    tail = self._rejected_since_tick
                    self._rejected_since_tick = 0
                    if tail:
                        self._ticks.append(TickStats(
                            batch=0, queue_depth=0, fill_ratio=0.0,
                            wait_ms=0.0, latencies_ms=(), rejected=tail))
                    return
                head = self._queue[0]
                deadline = head.t_submit + self.max_wait_ms / 1e3
                while (self._full_key() is None
                       and not (self._stop or self._flush)):
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cond.wait(timeout=remaining)
                # a full group anywhere in the queue outranks the partial
                # head tick; the head still dispatches by its deadline
                key = self._full_key() or self._queue[0].key
                reqs, rest = [], deque()
                while self._queue:
                    r = self._queue.popleft()
                    if r.key == key and len(reqs) < self.max_batch:
                        reqs.append(r)
                    else:
                        rest.append(r)
                depth = len(reqs) + len(rest)
                self._queue = rest
                rejected = self._rejected_since_tick
                self._rejected_since_tick = 0
                self._busy = True
            try:
                self._dispatch(reqs, depth, rejected)
            finally:
                with self._cond:
                    self._busy = False
                    self._cond.notify_all()

    def _dispatch(self, reqs: List[_Request], depth: int, rejected: int = 0):
        t_dispatch = time.monotonic()
        k, c = reqs[0].key
        epoch = None
        snap = None
        programs_before = _program_count()
        sp = trace.span("serve.tick", batch=len(reqs), depth=depth, k=k)
        try:
            with sp:
                if trace.is_enabled():
                    # retroactive cross-thread spans: each request's
                    # admission → dispatch queue wait, timed from its
                    # client-thread submit; inside the tick span so the
                    # records attribute to the tick that served them
                    for r in reqs:
                        trace.event("serve.queue_wait", r.t_submit,
                                    t_dispatch - r.t_submit, k=k)
                qs = pad_block(jnp.stack([r.q for r in reqs]),
                               self.max_batch)
                # Pin ONE index snapshot for the whole tick (module doc):
                # a hot-swap concurrent with this dispatch lands between
                # ticks, never inside one.
                snap_fn = getattr(self.engine, "current_snapshot", None)
                if snap_fn is not None:
                    snap = snap_fn()
                    epoch = getattr(snap, "epoch", None)
                    sp.set(epoch=epoch)
                    res = self.engine.query_batch_at(snap, qs, k=k, c=c)
                else:
                    res = self.engine.query_batch(qs, k=k, c=c)
                # One transfer for the whole tick: futures resolve to HOST
                # (numpy) QueryResults — per-request row views are
                # zero-copy, where B×fields device slices would dominate
                # the tick cost.
                host = jax.device_get(res)
        except Exception as e:                    # propagate to every caller
            for r in reqs:
                if not r.future.cancelled():
                    r.future.set_exception(e)
            # This tick records no TickStats — re-credit the rejects it
            # was carrying so the NEXT cut (or the terminal flush at
            # close) attributes them instead of dropping them.
            with self._cond:
                self._rejected_since_tick += rejected
            return
        now = time.monotonic()
        tick = TickStats(
            batch=len(reqs), queue_depth=depth,
            fill_ratio=len(reqs) / self.max_batch,
            wait_ms=(t_dispatch - reqs[0].t_submit) * 1e3,
            latencies_ms=tuple((now - r.t_submit) * 1e3 for r in reqs),
            rejected=rejected, epoch=epoch,
            compiles=max(0, _program_count() - programs_before))
        # Record the tick BEFORE resolving futures: a client that wakes
        # from f.result() must already see it in stats()/tick_log.
        with self._cond:
            self._ticks.append(tick)
        self._m_ticks.inc()
        if tick.compiles:
            self._m_compiles.inc(tick.compiles)
        self._m_depth.set(depth)
        self._m_fill.set(tick.fill_ratio)
        for r in reqs:
            self._m_wait.observe((t_dispatch - r.t_submit) * 1e3)
            self._m_latency.observe((now - r.t_submit) * 1e3)
        for i, r in enumerate(reqs):              # pad rows masked out here
            per_q = jax.tree_util.tree_map(lambda x, i=i: x[i], host)
            if not r.future.cancelled():
                r.future.set_result(per_q)
            if self.auditor is not None:
                self.auditor.observe(np.asarray(r.q), per_q, k=k, c=c,
                                     snapshot=snap)
