"""Online serving subsystem for the reverse k-ranks engine.

Two pieces, composable with any engine backend:

  scheduler — `MicroBatcher`: async `submit(q, k, c) -> Future` requests
              coalesced into `max_batch`-sized `engine.query_batch` ticks
              (partial ticks edge-padded to the compiled shape), with a
              `max_wait_ms` latency-vs-throughput knob and per-tick
              queue-depth / fill-ratio / p50-p99 latency stats.
  cache     — `CachingBackend`, registered as `"cached:<inner>"` in
              `repro.core.backends`: within-tick exact-duplicate dedupe
              plus a cross-tick LRU of per-query results keyed by
              (query bytes, k, c).

Robustness layer (PR 9), also here:

  faults    — deterministic fault injection at named sites (chaos tests,
              `perf_engine --faults`); disabled = one flag check.
  degrade   — `DegradePolicy` / `DegradeController`: the certified
              degrade ladder the scheduler steps down under sustained
              overload (and back up with hysteresis).

Typical serving stack (hot-query dedupe under micro-batching)::

    eng = ReverseKRanksEngine.build(users, items, cfg, key,
                                    backend="cached:fused")
    with MicroBatcher(eng, max_batch=16, max_wait_ms=2.0) as mb:
        fut = mb.submit(q, k=10, c=2.0, deadline_ms=50.0)
        res = fut.result()                 # per-query QueryResult
"""
# faults first: stdlib-only, imported by scheduler/maintenance/persist —
# loading it before cache keeps the partial-package window trivial
from repro.serve import faults
from repro.serve.cache import CachingBackend
from repro.serve.degrade import DegradeController, DegradePolicy
from repro.serve.scheduler import (DeadlineExceeded, MicroBatcher,
                                   QueueFull, REJECT_REASONS,
                                   SchedulerClosed, ServeStats, TickStats,
                                   pad_block)

__all__ = ["CachingBackend", "DeadlineExceeded", "DegradeController",
           "DegradePolicy", "MicroBatcher", "QueueFull", "REJECT_REASONS",
           "SchedulerClosed", "ServeStats", "TickStats", "faults",
           "pad_block"]
