"""Fault-tolerant checkpointing: atomic, step-tagged, elastic-reshardable.

Layout:  <dir>/step_<N>/
           manifest.json      — paths, shapes, dtypes, step, user metadata
           arrays.npz         — flattened leaves keyed by escaped path
         <dir>/LATEST         — atomically updated pointer file

Guarantees (tested in tests/test_checkpoint.py):
  * atomicity — a checkpoint is visible only after os.replace of its
    directory and the LATEST pointer; a killed writer leaves no partial
    step visible;
  * resume-exactness — restore() + the counter-based data pipeline replay
    reproduce the uninterrupted run bitwise (tests/dist kill/resume test);
  * elasticity — restore(shardings=...) device_puts every leaf to a NEW
    mesh layout, so a job can come back on a different topology.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree, metadata: Optional[dict] = None
         ) -> str:
    """Write one checkpoint atomically; returns its final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_step_{step}_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "|"): v for k, v in flat.items()})
        manifest = {
            "step": int(step),
            "keys": sorted(flat.keys()),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                      # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic LATEST pointer
    ptr_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(f"step_{step:08d}")
    os.replace(ptr_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    path = os.path.join(ckpt_dir, name)
    if not os.path.exists(os.path.join(path, "manifest.json")):
        return None                                  # torn pointer: ignore
    return int(name.split("_")[1])


def restore(ckpt_dir: str, template, step: Optional[int] = None,
            shardings=None) -> tuple[Any, int, dict]:
    """Load a checkpoint into `template`'s structure.

    shardings: optional pytree (same structure) of jax.sharding.Sharding —
    every leaf is device_put to it, enabling elastic mesh-shape changes.
    Returns (tree, step, metadata).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    npz = np.load(os.path.join(path, "arrays.npz"))
    flat = {k.replace("|", "/"): npz[k.replace("/", "|")]
            for k in manifest["keys"]}

    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_paths))
    out = []
    for (path_t, leaf), shd in zip(leaves_paths, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_t)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"shape mismatch for {key}: ckpt "
                             f"{arr.shape} vs template {want_shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return (jax.tree_util.tree_unflatten(treedef, out), step,
            manifest["metadata"])


def prune_old(ckpt_dir: str, keep: int = 3) -> None:
    """Keep the newest `keep` checkpoints (preemption-safe GC)."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
