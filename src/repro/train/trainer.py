"""Training step assembly: loss → grads → AdamW, with microbatch gradient
accumulation and the model's sharding rules applied at trace time.

`make_train_step` returns the exact function the launcher pjit-compiles for
the dry-run and that examples/train_lm.py runs for real on CPU.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.models.sharding import AxisRules, axis_rules
from repro.train.optimizer import (AdamWConfig, OptState, adamw_init,
                                   adamw_update, cosine_schedule)


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    rules: Optional[AxisRules] = None,
                    microbatches: int = 1,
                    schedule: Optional[Callable] = None,
                    bf16_compute_params: bool = True):
    """Returns train_step(params, opt_state, batch) → (params, opt_state,
    metrics). Microbatches split the global batch's leading dim and
    accumulate grads in a lax.scan (sequential, remat-friendly).

    bf16_compute_params (§Perf H3): cast f32 master weights to a bf16
    compute copy ONCE, constrained to the same (FSDP) sharding — GSPMD's
    per-layer weight all-gathers then move half the bytes. Grads flow back
    through the cast in f32; AdamW state stays f32 (mixed precision with
    master weights)."""
    specs = model.param_specs(rules) if rules is not None else None

    def _compute_params(params):
        if not bf16_compute_params:
            return params

        def cast(p, spec):
            if p.dtype != jnp.float32 or p.ndim < 2:
                return p                    # 1-D scales stay f32
            pc = p.astype(jnp.bfloat16)
            if rules is not None:
                pc = jax.lax.with_sharding_constraint(
                    pc, jax.NamedSharding(rules.mesh, spec))
            return pc

        if specs is None:
            return jax.tree.map(lambda p: cast(p, None), params)
        return jax.tree.map(cast, params, specs)

    def loss_fn(params, batch):
        return model.loss_fn(_compute_params(params), batch)

    def train_step(params, opt_state: OptState, batch: dict):
        with axis_rules(rules):
            if microbatches == 1:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            else:
                def split(x):
                    b = x.shape[0]
                    return x.reshape(microbatches, b // microbatches,
                                     *x.shape[1:])

                mb = jax.tree.map(split, batch)

                def acc(carry, mbatch):
                    l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                    return (carry[0] + l,
                            jax.tree.map(jnp.add, carry[1], g)), None

                zero = (jnp.zeros(()),
                        jax.tree.map(lambda p: jnp.zeros(p.shape,
                                                         jnp.float32),
                                     params))
                (loss, grads), _ = jax.lax.scan(acc, zero, mb)
                loss = loss / microbatches
                grads = jax.tree.map(lambda g: g / microbatches, grads)

            lr_scale = schedule(opt_state.step) if schedule else 1.0
            params, opt_state, om = adamw_update(opt_cfg, grads, opt_state,
                                                 params, lr_scale)
        return params, opt_state, {"loss": loss, **om}

    return train_step


def make_serve_step(model: Model, rules: Optional[AxisRules] = None):
    """Returns serve_step(params, cache, tokens) → (logits, cache): one
    batched decode step — the function the decode cells lower."""

    def serve_step(params, cache, tokens):
        with axis_rules(rules):
            return model.decode_step(params, cache, tokens)

    return serve_step


def make_prefill_step(model: Model, rules: Optional[AxisRules] = None):
    """Returns prefill(params, batch) → logits over the full sequence."""

    def prefill(params, batch):
        with axis_rules(rules):
            return model.forward_logits(params, batch["tokens"],
                                        frames=batch.get("frames"))

    return prefill


def init_train_state(model: Model, key) -> tuple[dict, OptState]:
    params = model.init_params(key)
    return params, adamw_init(params)
