"""AdamW + global-norm clipping in pure JAX (no optax in this container).

State is a pytree mirroring params; `adamw_init` / `adamw_update` compose
with pjit: optimizer state inherits parameter shardings (ZeRO-1 style
sharding is applied by the launcher via the same param specs).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class OptState(NamedTuple):
    mu: dict
    nu: dict
    step: jax.Array


def adamw_init(params) -> OptState:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return OptState(mu=zeros(params), nu=zeros(params),
                    step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, state: OptState, params,
                 lr_scale: jax.Array | float = 1.0):
    """One AdamW step with global-norm clipping. Returns (params, state,
    metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        p_new = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p)
        return p_new.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    params_new = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    mu_new = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    nu_new = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return params_new, OptState(mu_new, nu_new, step), {
        "grad_norm": gnorm, "lr": lr}


def cosine_schedule(step: jax.Array, *, warmup: int, total: int,
                    min_frac: float = 0.1) -> jax.Array:
    """Linear warmup → cosine decay to min_frac (as a multiplier on lr)."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, cos)
