"""rwkv6-7b — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892; hf]
32L d_model=4096 d_ff=14336 vocab=65536."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="rwkv",
    n_layers=32,
    d_model=4096,
    n_heads=32,            # WKV heads: hd = 128
    n_kv_heads=32,
    head_dim=128,
    d_ff=14_336,
    vocab=65_536,
    act="relu_sq",         # channel-mix uses relu²; act unused by tmix
)
