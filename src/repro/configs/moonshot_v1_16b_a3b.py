"""moonshot-v1-16b-a3b — kimi/moonlight MoE, 64e top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]
48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=163_840,
    act="swiglu",
    n_experts=64,
    experts_per_tok=6,
    moe_d_ff=1408,
)
