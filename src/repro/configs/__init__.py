"""Architecture registry: `--arch <id>` resolves here.

Each assigned architecture has one module with the exact published config;
`reduced(cfg)` derives the CPU smoke-test variant (same family/topology,
tiny dims)."""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCH_IDS = (
    "llama4-scout-17b-a16e",
    "moonshot-v1-16b-a3b",
    "recurrentgemma-9b",
    "granite-3-8b",
    "qwen3-32b",
    "gemma-2b",
    "phi3-medium-14b",
    "chameleon-34b",
    "rwkv6-7b",
    "whisper-medium",
)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(
        f"repro.configs.{arch_id.replace('-', '_')}")
    return mod.CONFIG


def reduced(cfg: ModelConfig, *, layers: int = 2) -> ModelConfig:
    """Smoke-test shrink: same family / block pattern / attention topology,
    small widths, tiny vocab. Keeps every structural trait (GQA ratio,
    qk-norm, MoE top-k, hybrid pattern, enc-dec) so the smoke test runs the
    same code paths as the full config."""
    g = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)   # keep the GQA ratio
    kv = 1 if cfg.n_kv_heads == 1 else 2
    heads = kv * g
    if cfg.family == "rwkv":                 # wkv needs H·hd == d_model
        heads = kv = 64 // 16
    pat_len = len(cfg.block_pattern) or 1
    n_layers = max(layers, pat_len + (1 if cfg.family == "hybrid" else 0))
    changes = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=128,
        vocab=512,
        rwkv_lora_dim=8,
    )
    if cfg.family == "moe":
        changes.update(n_experts=max(cfg.n_experts // 8, 4),
                       experts_per_tok=min(cfg.experts_per_tok, 2),
                       moe_d_ff=64, moe_group_tokens=256)
    if cfg.family == "hybrid":
        changes.update(rnn_width=64, local_window=16)
    if cfg.family == "encdec":
        changes.update(n_enc_layers=2, enc_seq=24)
    return dataclasses.replace(cfg, **changes)
