"""llama4-scout-17b-a16e — MoE, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202_048,
    act="swiglu",
    n_experts=16,
    experts_per_tok=1,
    moe_d_ff=8192,
)
