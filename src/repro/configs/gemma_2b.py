"""gemma-2b — GeGLU, head_dim=256, MQA. [arXiv:2403.08295; hf]
18L d_model=2048 8H (kv=1) d_ff=16384 vocab=256000."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab=256_000,
    act="geglu",
    tie_embeddings=True,
)
