"""Paper-engine configurations: rank-table parameters and the paper's
dataset scales (§5), used by benchmarks and the engine dry-run."""
import dataclasses

from repro.core.types import RankTableConfig

# Paper defaults after the Table-1 tuning (τ = 500).
DEFAULT_TABLE = RankTableConfig(tau=500, omega=10, s=64)


@dataclasses.dataclass(frozen=True)
class DatasetScale:
    name: str
    n_users: int
    n_items: int
    d: int = 200            # the paper's MF embedding dimensionality


# Exact §5 dataset sizes (full scale exercised via dry-run / sharded build;
# CPU benchmarks run reduced replicas of the same norm distribution).
AMAZON_K = DatasetScale("amazon-k", 1_406_890, 430_530)
MOVIELENS = DatasetScale("movielens", 162_541, 59_047)
NETFLIX = DatasetScale("netflix", 480_189, 17_770)
DATASETS = {d.name: d for d in (AMAZON_K, MOVIELENS, NETFLIX)}

# §5 protocol: 1000 random item queries; k and c sweeps from Figs. 3-4.
N_QUERIES = 1000
K_SWEEP = (10, 20, 30, 40, 50)
C_SWEEP = (1.5, 2.0, 2.5, 3.0)
