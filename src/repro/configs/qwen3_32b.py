"""qwen3-32b — dense GQA with qk-norm. [hf:Qwen/Qwen3-8B; hf]
64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,          # qwen3 fixes head_dim=128 (≠ d_model/n_heads)
    d_ff=25_600,
    vocab=151_936,
    act="swiglu",
    qk_norm=True,
)
