"""recurrentgemma-9b — RG-LRU + local attention, 1:2 pattern.
[arXiv:2402.19427; unverified]
38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12_288,
    vocab=256_000,
    act="geglu",
    block_pattern=("rglru", "rglru", "local_attn"),
    rnn_width=4096,
    local_window=2048,
)
