"""whisper-medium — enc-dec, conv frontend STUB (precomputed frame
embeddings via input_specs). [arXiv:2212.04356; unverified]
24L enc + 24L dec, d_model=1024 16H (kv=16) d_ff=4096 vocab=51865."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51_865,
    act="gelu",
    enc_seq=1500,
)
