"""chameleon-34b — early-fusion VLM, VQ image tokens share the vocab.
[arXiv:2405.09818; unverified]
48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.
The modality frontend is a STUB per the assignment: image patches arrive
as VQ token ids inside the ordinary token stream."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="dense",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22_016,
    vocab=65_536,
    act="swiglu",
    qk_norm=True,          # chameleon stabilizes with qk-norm
)
