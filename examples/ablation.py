"""Ablation study: what each piece of Algorithm 1 buys.

Sweeps (ω, s, threshold_mode) at fixed τ and reports accuracy / overall
ratio / build time — quantifying the paper's claim that NORM-STRATIFIED
sampling (ω > 1) beats plain random sampling (ω = 1) on Gaussian-norm data.

    PYTHONPATH=src python examples/ablation.py
"""
import time

import jax
import numpy as np

from repro.core import ReverseKRanksEngine, RankTableConfig, metrics
from repro.core.exact import exact_ranks, reverse_k_ranks
from repro.data.pipeline import synthetic_embeddings

N, M, D, K, C = 12_000, 5_000, 200, 10, 2.0
N_EVAL = 10

key = jax.random.PRNGKey(0)
users, items = synthetic_embeddings(key, N, M, D, norm_spread=0.45)

print(f"{'config':38s} {'acc':>6s} {'ratio':>7s} {'build_s':>8s}")
for omega, s, mode in [
    (1, 640, "sampled"),        # plain random sampling, same budget
    (10, 64, "sampled"),        # the paper's stratified default
    (40, 16, "sampled"),        # over-stratified
    (10, 64, "norm_bound"),     # footnote-1 O(1) threshold range
    (10, 16, "sampled"),        # 4× smaller budget
]:
    cfg = RankTableConfig(tau=500, omega=omega, s=s, threshold_mode=mode)
    t0 = time.time()
    eng = ReverseKRanksEngine.build(users, items, cfg, jax.random.PRNGKey(1))
    jax.block_until_ready(eng.rank_table.table)
    build = time.time() - t0
    accs, ratios = [], []
    for qi in range(N_EVAL):
        q = items[qi * 97]
        truth = np.asarray(exact_ranks(users, items, q))
        ex_idx, _ = reverse_k_ranks(users, items, q, K)
        r = eng.query(q, k=K, c=C)
        accs.append(metrics.accuracy(np.asarray(r.indices),
                                     np.asarray(ex_idx), truth, C))
        ratios.append(metrics.overall_ratio(np.asarray(r.indices),
                                            np.asarray(ex_idx), truth))
    name = f"omega={omega},s={s},mode={mode}"
    print(f"{name:38s} {np.mean(accs):6.3f} {np.mean(ratios):7.3f} "
          f"{build:8.2f}")
