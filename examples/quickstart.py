"""Quickstart: build a rank-table index and answer c-approximate reverse
k-ranks queries (the paper's end-to-end flow in ~40 lines).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import ReverseKRanksEngine, RankTableConfig, metrics
from repro.core.exact import exact_ranks, reverse_k_ranks
from repro.data.pipeline import synthetic_embeddings

N_USERS, N_ITEMS, DIM = 10_000, 4_000, 200
K, C = 10, 2.0

key = jax.random.PRNGKey(0)
users, items = synthetic_embeddings(key, N_USERS, N_ITEMS, DIM)

# --- offline: Algorithm 1 (O((n+m)d + m log m), vs QSRP's Ω(nmd)) --------
engine = ReverseKRanksEngine.build(
    users, items, RankTableConfig(tau=500, omega=10, s=64),
    jax.random.PRNGKey(1))
print(f"index built: {engine.memory_bytes() / 2**20:.1f} MiB "
      f"for {N_USERS:,} users")

# --- online: O(nd) per query ---------------------------------------------
query_item = items[42]
result = engine.query(query_item, k=K, c=C)
print(f"top-{K} users for item 42: {np.asarray(result.indices).tolist()}")
print(f"estimated ranks: {np.round(np.asarray(result.est_rank), 1)}")
print(f"Lemma-1 closed the search in step 2: {bool(result.guaranteed)} "
      f"(accepted={int(result.n_accepted)}, pruned={int(result.n_pruned)})")

# --- verify against the exact O(nmd) oracle -------------------------------
truth = np.asarray(exact_ranks(users, items, query_item))
exact_idx, exact_rk = reverse_k_ranks(users, items, query_item, K)
acc = metrics.accuracy(np.asarray(result.indices), np.asarray(exact_idx),
                       truth, c=C)
ratio = metrics.overall_ratio(np.asarray(result.indices),
                              np.asarray(exact_idx), truth)
print(f"accuracy={acc:.3f}  overall-ratio={ratio:.3f}  "
      f"(exact best ranks: {np.asarray(exact_rk)[:5].tolist()}…)")
