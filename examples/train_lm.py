"""End-to-end training driver (deliverable b): train a ~100M-parameter
dense LM for a few hundred steps on the deterministic synthetic pipeline,
with checkpointing and resume.

    PYTHONPATH=src python examples/train_lm.py            # ~100M, 200 steps
    PYTHONPATH=src python examples/train_lm.py --tiny     # CI-speed

The same run_training() drives the full configs on real accelerators via
`python -m repro.launch.train --arch <id> --full-config`.
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_config
from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="CI-speed run")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    base = get_config("granite-3-8b")
    if args.tiny:
        cfg = dataclasses.replace(
            base, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
            head_dim=32, d_ff=512, vocab=2048, remat="none")
        steps, gb, seq = args.steps or 30, 4, 64
    else:
        # ~100M params: 12L × d512 (GQA 8/2) × ff2048, 32k vocab
        cfg = dataclasses.replace(
            base, n_layers=12, d_model=512, n_heads=8, n_kv_heads=2,
            head_dim=64, d_ff=2048, vocab=32_768, remat="none")
        steps, gb, seq = args.steps or 200, 8, 256

    with tempfile.TemporaryDirectory() as ckpt_dir:
        _, losses = run_training(cfg, steps=steps, global_batch=gb,
                                 seq_len=seq, ckpt_dir=ckpt_dir,
                                 ckpt_every=max(steps // 4, 10), lr=1e-3,
                                 log_every=max(steps // 20, 1))
    drop = losses[0] - losses[-1]
    print(f"\nloss {losses[0]:.3f} → {losses[-1]:.3f} "
          f"(drop {drop:.3f} over {steps} steps)")
    assert drop > 0.3, "training did not learn — investigate"
    print("OK")


if __name__ == "__main__":
    main()
