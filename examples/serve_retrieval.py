"""Item-centric retrieval serving (deliverable b): the full paper pipeline
— ratings → JAX matrix factorization → rank-table index → ONLINE
c-approximate reverse k-ranks serving → §5 metrics, plus backbone-encoded
embeddings to show the engine composes with the assigned architectures.

    PYTHONPATH=src python examples/serve_retrieval.py

Serving model (repro.serve): queries arrive one at a time and are
`submit()`-ed to a MicroBatcher, which coalesces them into max_batch-
sized ticks dispatched through `engine.query_batch` — one rank-table
pass per tick. `max_wait_ms` is the latency-vs-throughput knob: it caps
how long a PARTIAL tick waits for more arrivals before dispatching
(padded to the compiled batch shape). Small values bound tail latency at
low offered load; larger values raise the fill ratio and the per-query
bandwidth amortization — benchmarks/perf_engine.py --serve measures the
whole curve. The "cached:<inner>" backend wrapper adds within-tick
duplicate dedupe and a cross-tick per-query LRU for hot items.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import ReverseKRanksEngine, RankTableConfig, metrics
from repro.core.exact import exact_ranks, reverse_k_ranks
from repro.data.mf import MFConfig, embeddings, train_mf
from repro.data.pipeline import synthetic_ratings
from repro.models.model import Model
from repro.models import transformer as T
from repro.serve import MicroBatcher

N_USERS, N_ITEMS, K, C = 6_000, 2_500, 10, 2.0

# --- 1. ratings → MF embeddings (the paper's LIBMF step, in JAX) ----------
key = jax.random.PRNGKey(0)
ii, jj, rr = synthetic_ratings(key, N_USERS, N_ITEMS, n_obs=300_000)
# mean-loss SGD scales the per-example step by 1/batch ⇒ lr = O(10) here
state, losses = train_mf(key, N_USERS, N_ITEMS, ii, jj, rr,
                         MFConfig(d=64, epochs=8, lr=10.0))
users, items = embeddings(state)
print(f"MF: rmse-ish loss {losses[0]:.4f} → {losses[-1]:.4f}, "
      f"embeddings d={users.shape[1]}")

# --- 2. offline index ------------------------------------------------------
# backend= selects a query-execution backend from the registry
# (repro.core.backends): "dense" (pure jnp), "fused" (Pallas), "sharded",
# or a wrapped spec — "cached:dense" dedupes duplicate queries within a
# tick and LRU-caches per-query results across ticks (hot promoted items
# are answered without touching the rank table).
eng = ReverseKRanksEngine.build(users, items,
                                RankTableConfig(tau=500, omega=10, s=64),
                                jax.random.PRNGKey(1), backend="cached:dense")

# --- 3. async online serving ----------------------------------------------
# Single queries are submitted to the MicroBatcher as they "arrive"; ticks
# of up to max_batch dispatch through query_batch, which reads the (n, τ)
# rank table ONCE per tick (the bandwidth amortization of
# benchmarks/perf_engine.py --batched, now reachable from a one-query-at-
# a-time client). max_wait_ms caps how long a partial tick waits to fill.
qidx = jax.random.randint(jax.random.PRNGKey(2), (16,), 0, N_ITEMS)
qs = items[qidx]
# warm-up compiles the tick shape with PERTURBED queries (different cache
# keys), so the timed burst below exercises the real micro-batched
# dispatch path, not 16 LRU hits of the warm-up's results.
warm = eng.query_batch(qs * (1.0 + 1e-6), k=K, c=C)
jax.block_until_ready(warm.indices)
with MicroBatcher(eng, max_batch=16, max_wait_ms=2.0) as mb:
    t0 = time.time()
    futs = [mb.submit(q, K, C) for q in qs]          # duplicate-free burst
    results = [f.result() for f in futs]
    wall = time.time() - t0
    print(f"served {len(futs)} queries in {wall*1e3:.1f} ms wall "
          f"({eng.backend_name} backend): {mb.stats()}")

accs, ratios = [], []
for b in range(8):
    q = qs[b]
    truth = np.asarray(exact_ranks(users, items, q))
    ex_idx, _ = reverse_k_ranks(users, items, q, K)
    accs.append(metrics.accuracy(np.asarray(results[b].indices),
                                 np.asarray(ex_idx), truth, C))
    ratios.append(metrics.overall_ratio(np.asarray(results[b].indices),
                                        np.asarray(ex_idx), truth))
print(f"accuracy {np.mean(accs):.3f}  overall-ratio {np.mean(ratios):.3f}")

# --- 4. backbone-encoded embeddings (engine ∘ assigned architecture) ------
cfg = reduced(get_config("gemma-2b"))
model = Model(cfg)
params = model.init_params(jax.random.PRNGKey(3))
tok_u = jax.random.randint(jax.random.PRNGKey(4), (256, 16), 0, cfg.vocab)
tok_i = jax.random.randint(jax.random.PRNGKey(5), (128, 16), 0, cfg.vocab)


def encode(tokens):
    x = T._embed(params, tokens, cfg)
    x = T._apply_segments(params["segments"], cfg.segments(), x, cfg,
                          jnp.arange(tokens.shape[1]))
    return x.mean(axis=1).astype(jnp.float32)       # mean-pooled d_model


u_emb, i_emb = encode(tok_u), encode(tok_i)
eng2 = ReverseKRanksEngine.build(u_emb, i_emb,
                                 RankTableConfig(tau=64, omega=4, s=16),
                                 jax.random.PRNGKey(6))
r2 = eng2.query(i_emb[7], k=5, c=2.0)
print(f"backbone-embedded reverse 5-ranks for item 7 → users "
      f"{np.asarray(r2.indices).tolist()} (engine composes with any arch)")
