"""Item-centric retrieval serving (deliverable b): the full paper pipeline
— ratings → JAX matrix factorization → rank-table index → batched
c-approximate reverse k-ranks queries → §5 metrics, plus backbone-encoded
embeddings to show the engine composes with the assigned architectures.

    PYTHONPATH=src python examples/serve_retrieval.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import ReverseKRanksEngine, RankTableConfig, metrics
from repro.core.exact import exact_ranks, reverse_k_ranks
from repro.data.mf import MFConfig, embeddings, train_mf
from repro.data.pipeline import synthetic_ratings
from repro.models.model import Model
from repro.models import transformer as T

N_USERS, N_ITEMS, K, C = 6_000, 2_500, 10, 2.0

# --- 1. ratings → MF embeddings (the paper's LIBMF step, in JAX) ----------
key = jax.random.PRNGKey(0)
ii, jj, rr = synthetic_ratings(key, N_USERS, N_ITEMS, n_obs=300_000)
# mean-loss SGD scales the per-example step by 1/batch ⇒ lr = O(10) here
state, losses = train_mf(key, N_USERS, N_ITEMS, ii, jj, rr,
                         MFConfig(d=64, epochs=8, lr=10.0))
users, items = embeddings(state)
print(f"MF: rmse-ish loss {losses[0]:.4f} → {losses[-1]:.4f}, "
      f"embeddings d={users.shape[1]}")

# --- 2. offline index ------------------------------------------------------
# backend= selects a query-execution backend from the registry
# (repro.core.backends): "dense" (pure jnp), "fused" (Pallas), "sharded".
eng = ReverseKRanksEngine.build(users, items,
                                RankTableConfig(tau=500, omega=10, s=64),
                                jax.random.PRNGKey(1), backend="dense")

# --- 3. batched online queries --------------------------------------------
# query_batch reads the (n, τ) rank table ONCE per batch — per-query cost
# drops as B grows (the table-bandwidth amortization; see
# benchmarks/perf_engine.py --batched for the full curve).
qidx = jax.random.randint(jax.random.PRNGKey(2), (16,), 0, N_ITEMS)
qs = items[qidx]
for B in (1, 16):
    res = eng.query_batch(qs[:B], k=K, c=C)           # warm-up/compile
    jax.block_until_ready(res.indices)
    t0 = time.time()
    res = eng.query_batch(qs[:B], k=K, c=C)
    jax.block_until_ready(res.indices)
    print(f"batched queries: {(time.time()-t0)/B*1e3:.2f} ms/query "
          f"(batch of {B}, {eng.backend_name} backend)")

res = eng.query_batch(qs[:8], k=K, c=C)          # metrics on 8 queries

accs, ratios = [], []
for b in range(8):
    q = qs[b]
    truth = np.asarray(exact_ranks(users, items, q))
    ex_idx, _ = reverse_k_ranks(users, items, q, K)
    accs.append(metrics.accuracy(np.asarray(res.indices[b]),
                                 np.asarray(ex_idx), truth, C))
    ratios.append(metrics.overall_ratio(np.asarray(res.indices[b]),
                                        np.asarray(ex_idx), truth))
print(f"accuracy {np.mean(accs):.3f}  overall-ratio {np.mean(ratios):.3f}")

# --- 4. backbone-encoded embeddings (engine ∘ assigned architecture) ------
cfg = reduced(get_config("gemma-2b"))
model = Model(cfg)
params = model.init_params(jax.random.PRNGKey(3))
tok_u = jax.random.randint(jax.random.PRNGKey(4), (256, 16), 0, cfg.vocab)
tok_i = jax.random.randint(jax.random.PRNGKey(5), (128, 16), 0, cfg.vocab)


def encode(tokens):
    x = T._embed(params, tokens, cfg)
    x = T._apply_segments(params["segments"], cfg.segments(), x, cfg,
                          jnp.arange(tokens.shape[1]))
    return x.mean(axis=1).astype(jnp.float32)       # mean-pooled d_model


u_emb, i_emb = encode(tok_u), encode(tok_i)
eng2 = ReverseKRanksEngine.build(u_emb, i_emb,
                                 RankTableConfig(tau=64, omega=4, s=16),
                                 jax.random.PRNGKey(6))
r2 = eng2.query(i_emb[7], k=5, c=2.0)
print(f"backbone-embedded reverse 5-ranks for item 7 → users "
      f"{np.asarray(r2.indices).tolist()} (engine composes with any arch)")
